"""ScratchPipe: the pipelined always-hit embedding cache runtime (paper §IV).

Six-stage pipeline over mini-batches, one training iteration completing per
pipeline cycle at steady state:

    [Plan] -> [Collect] -> [Exchange] -> [Insert] -> [Train(fwd+bwd+update)]

Stage execution inside a cycle is deliberately ordered ADVERSARIALLY w.r.t.
the paper's RAW hazards — [Collect] of the newest in-flight batch runs
*before* [Insert]/[Train] of older batches — so any hold-window bug surfaces
as stale data instead of being masked by sequential execution. With the
paper's window (3 past + current + 2 future) execution is equivalent to
sequential training (tested bit-tight in tests/test_scratchpipe_properties).

``train_fn(storage, slots, batch) -> (storage, aux)`` is the [Train] stage —
any jitted computation that gathers from the scratchpad with ``slots`` and
updates those rows in place (DLRM step, LM embedding step, ...).

The runtime also keeps per-tier byte counters ([Collect]/[Insert] host bytes,
[Exchange] PCIe bytes, [Train] HBM bytes) — these feed the calibrated
bandwidth model reproducing the paper's latency figures.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, Deque, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.core import scratchpad as sp
from repro.core.host_table import HostEmbeddingTable, HostTraffic
from repro.core.plan import Planner, PlanResult
from repro.core.runtime import register_runtime
from repro.core.table_group import TableGroup


@dataclasses.dataclass
class StepStats:
    step: int
    n_lookups: int
    n_unique: int
    n_hits: int
    n_miss: int
    n_evict: int
    hit_lookups: int = 0  # lookup-level (non-unique) hit count
    by_table: Any = None  # per-table {hits, misses} (multi-table runs only)
    aux: Any = None

    @property
    def hit_rate(self) -> float:
        return self.n_hits / max(self.n_unique, 1)


@dataclasses.dataclass
class _InFlight:
    ids: np.ndarray
    batch: Any
    plan: Optional[PlanResult] = None
    host_rows: Optional[np.ndarray] = None  # [Collect] host->staging
    evicted_dev: Optional[jax.Array] = None  # [Collect] device victim read
    fetched_dev: Optional[jax.Array] = None  # [Exchange] h2d
    evicted_host: Optional[np.ndarray] = None  # [Exchange] d2h
    stage: int = 0  # stages completed: 1=planned .. 4=inserted


class ScratchPipe:
    def __init__(
        self,
        host_table: HostEmbeddingTable,
        num_slots: int,
        train_fn: Callable[[jax.Array, jax.Array, Any], Tuple[jax.Array, Any]],
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        pipelined: bool = True,
        storage_dtype=None,
        table_group: Optional[TableGroup] = None,
        slot_budgets=None,
    ):
        self.host = host_table
        self.train_fn = train_fn
        self.pipelined = pipelined
        self.table_group = table_group
        if not pipelined:  # straw-man (§IV-B): depth-1, no hazards possible
            past_window, future_window = 0, 0
        if table_group is not None:
            if table_group.total_rows != host_table.rows:
                raise ValueError(
                    f"table_group covers {table_group.total_rows} rows, "
                    f"host table has {host_table.rows}"
                )
            budgets = (
                list(slot_budgets)
                if slot_budgets is not None
                else table_group.slot_budgets(num_slots)
            )
            if sum(budgets) > num_slots:
                raise ValueError(
                    f"slot budgets {budgets} exceed num_slots={num_slots}"
                )
            row_offsets = table_group.offsets
            slot_ranges = table_group.slot_ranges(budgets)
        else:
            row_offsets = slot_ranges = None
        self.planner = Planner(
            host_table.rows,
            num_slots,
            past_window=past_window,
            future_window=future_window,
            policy=policy,
            row_offsets=row_offsets,
            slot_ranges=slot_ranges,
        )
        import jax.numpy as jnp

        dt = storage_dtype or jnp.dtype(host_table.data.dtype.name)
        self.storage = sp.make_storage(num_slots, host_table.dim, dt)
        self.pcie = HostTraffic()  # read = d2h, written = h2d
        self.hbm = HostTraffic()  # device-side traffic ([Train] + fills)
        self._window: Deque[_InFlight] = collections.deque()
        self._stats: List[StepStats] = []
        self.future_window = future_window

    # ------------------------------------------------------------------ #
    # stages
    # ------------------------------------------------------------------ #
    def _stage_plan(self, entry: _InFlight, lookahead: List[np.ndarray]):
        entry.plan = self.planner.plan(entry.ids, lookahead)

    def _stage_collect(self, entry: _InFlight):
        p = entry.plan
        entry.host_rows = self.host.gather(p.miss_ids)  # host-tier read
        entry.evicted_dev = sp.read(self.storage, p.evict_slots)  # HBM read
        self.hbm.read += p.evict_slots.size * self.host.row_bytes

    def _stage_exchange(self, entry: _InFlight):
        p = entry.plan
        entry.fetched_dev = jax.device_put(entry.host_rows)  # h2d
        entry.evicted_host = np.asarray(entry.evicted_dev)  # d2h
        self.pcie.written += p.miss_ids.size * self.host.row_bytes
        self.pcie.read += p.evict_slots.size * self.host.row_bytes

    def _stage_insert(self, entry: _InFlight):
        p = entry.plan
        if p.evict_ids.size:
            self.host.scatter(p.evict_ids, entry.evicted_host)  # host write
        if p.fill_slots.size:
            self.storage = sp.fill(
                self.storage, jax.device_put(p.fill_slots), entry.fetched_dev
            )
            self.hbm.written += p.fill_slots.size * self.host.row_bytes

    def _stage_train(self, entry: _InFlight) -> StepStats:
        p = entry.plan
        self.storage, aux = self.train_fn(
            self.storage, jax.device_put(p.slots), entry.batch
        )
        # [Train] HBM traffic: gather reads + coalesced scatter read-mod-write
        self.hbm.read += p.slots.size * self.host.row_bytes
        self.hbm.read += p.n_unique * self.host.row_bytes
        self.hbm.written += p.n_unique * self.host.row_bytes
        by_table = None
        if p.hits_by_table is not None:
            by_table = {"hits": p.hits_by_table, "misses": p.misses_by_table}
        st = StepStats(
            step=p.step,
            n_lookups=int(p.slots.size),
            n_unique=p.n_unique,
            n_hits=p.n_hits,
            n_miss=int(p.miss_ids.size),
            n_evict=int(p.evict_slots.size),
            hit_lookups=int(p.slots.size),  # always-hit at [Train] (§IV)
            by_table=by_table,
            aux=aux,
        )
        self._stats.append(st)
        return st

    # ------------------------------------------------------------------ #
    # pipeline driver
    # ------------------------------------------------------------------ #
    def run(
        self, stream: Iterator[Tuple[np.ndarray, Any]], lookahead_fn=None
    ) -> List[StepStats]:
        """stream yields (sparse_ids, batch_payload). ``lookahead_fn(k)``
        returns the ids of the next k mini-batches WITHOUT consuming them
        (see repro.data.lookahead). Returns per-step stats (train order)."""
        if not self.pipelined:
            return self._run_sequential(stream, lookahead_fn)
        out: List[StepStats] = []
        it = iter(stream)
        draining = False
        while True:
            if not draining:
                # Streams exposing ``exhausted`` (LookaheadStream,
                # TraceReplayStream) are asked directly — a short look-ahead
                # window near the end already told them, so the drain
                # decision never rests on a sentinel next() probe.
                if getattr(stream, "exhausted", False):
                    draining = True
                else:
                    try:
                        ids, batch = next(it)
                    except StopIteration:
                        draining = True
                    else:
                        entry = _InFlight(np.asarray(ids), batch)
                        la = (
                            lookahead_fn(self.future_window)
                            if lookahead_fn
                            else []
                        )
                        self._stage_plan(entry, la)
                        entry.stage = 1
                        self._window.append(entry)
            self._advance_cycle(out)
            if draining and not self._window:
                break
        return out

    def _advance_cycle(self, out: List[StepStats]):
        """One pipeline cycle: every in-flight entry advances exactly one
        stage (entries entered on different cycles, so their stage indices
        are all distinct). Execution order inside the cycle is the
        hazard-adversarial one — the newest batch's [Collect] reads host and
        scratchpad state BEFORE the older batches' [Insert] write-back and
        [Train] update run. A missing hold-window rule therefore produces
        stale reads (caught by the property tests) instead of being hidden
        by sequential execution."""
        by_stage = {e.stage: e for e in self._window}
        if 1 in by_stage:
            self._stage_collect(by_stage[1])
        if 2 in by_stage:
            self._stage_exchange(by_stage[2])
        if 3 in by_stage:
            self._stage_insert(by_stage[3])
        if 4 in by_stage:
            entry = by_stage[4]
            out.append(self._stage_train(entry))
            self._window.remove(entry)
        for s in (1, 2, 3):
            if s in by_stage:
                by_stage[s].stage = s + 1

    # -- incremental driving (lockstep multi-shard execution, §VI-G) ------- #
    def run_one_cycle(self, ids, batch, lookahead_fn=None) -> Optional[StepStats]:
        """Plan one new mini-batch and advance the pipeline one cycle."""
        entry = _InFlight(np.asarray(ids), batch)
        la = lookahead_fn(self.future_window) if lookahead_fn else []
        self._stage_plan(entry, la)
        entry.stage = 1
        self._window.append(entry)
        out: List[StepStats] = []
        self._advance_cycle(out)
        return out[0] if out else None

    def drain_one_cycle(self) -> Optional[StepStats]:
        """Advance one cycle without a new batch (pipeline drain)."""
        out: List[StepStats] = []
        self._advance_cycle(out)
        return out[0] if out else None

    def _run_sequential(self, stream, lookahead_fn) -> List[StepStats]:
        """Straw-man (§IV-B): dynamic cache, no pipelining — every batch runs
        Plan/Collect/Exchange/Insert/Train back-to-back."""
        out = []
        for ids, batch in stream:
            entry = _InFlight(np.asarray(ids), batch)
            self._stage_plan(entry, [])
            self._stage_collect(entry)
            self._stage_exchange(entry)
            self._stage_insert(entry)
            out.append(self._stage_train(entry))
        return out

    # ------------------------------------------------------------------ #
    def flush_to_host(self):
        """Write every cached (dirty) row back to the host table."""
        live = np.flatnonzero(self.planner.slot_to_id >= 0)
        if live.size:
            ids = self.planner.slot_to_id[live]
            vals = np.asarray(sp.read(self.storage, live))
            self.host.scatter(ids, vals)

    # -- checkpoint/restart (paper-system fault tolerance) ----------------- #
    def state_arrays(self) -> dict:
        """Host-side snapshot at a pipeline-drain boundary (no in-flight
        batches): planner state + scratchpad contents + host table. Together
        with the deterministic look-ahead stream position this resumes with
        an IDENTICAL schedule (tests/test_perf_flags_and_ft.py)."""
        assert not self._window, "checkpoint only at drain boundaries"
        out = {"host_table": self.host.data, "storage": np.asarray(self.storage)}
        for k, v in self.planner.state_dict().items():
            out[f"planner_{k}"] = v
        return out

    def load_state_arrays(self, arrays: dict) -> None:
        assert not self._window
        self.host.data = np.asarray(arrays["host_table"])
        self.storage = jax.device_put(np.asarray(arrays["storage"]))
        self.planner.load_state_dict(
            {k[len("planner_"):]: v for k, v in arrays.items()
             if k.startswith("planner_")}
        )

    @property
    def stats(self) -> List[StepStats]:
        return self._stats

    def traffic(self) -> dict:
        return {"host": self.host.traffic, "pcie": self.pcie, "hbm": self.hbm}


@register_runtime("scratchpipe")
def _make_scratchpipe(host_table, train_fn, *, num_slots, **kw) -> ScratchPipe:
    return ScratchPipe(host_table, num_slots, train_fn, **kw)


@register_runtime("strawman")
def _make_strawman(host_table, train_fn, *, num_slots, **kw) -> ScratchPipe:
    kw.pop("pipelined", None)
    return ScratchPipe(host_table, num_slots, train_fn, pipelined=False, **kw)
