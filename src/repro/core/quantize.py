"""Mixed-precision scratchpad rows: quantize/dequantize + byte accounting.

The host table always keeps fp32 *master* rows; the scratchpad may hold a
reduced-precision *replica* of each resident row (arXiv:2010.11305). At an
equal byte budget the replica precision multiplies the resident working
set: fp16 rows are 2x smaller, int8 rows 4x. The coherence rule is
one-directional and simple:

* master -> replica: quantize on [Collect] (host side, before h2d, so the
  PCIe transfer already moves the small rows);
* replica -> master: dequantize on write-back ([Insert]-host for evictions,
  ``flush_to_host`` at the end) — the fp32 master simply receives the
  dequantized replica, which holds every in-cache update the row saw while
  resident;
* in-cache updates re-quantize through ``requantize_update`` (optionally
  with stochastic rounding so repeated small updates are unbiased instead
  of being swallowed by round-to-nearest).

Quantization formats
--------------------
``fp16``   plain ``float16`` rows, round-to-nearest-even on quantize.
``int8``   symmetric per-row scale: ``scale = max|row| / 127`` (1.0 for
           all-zero rows), ``q = clip(round(row / scale), -127, 127)``,
           ``dequant = q * scale``. The fp32 scale column is the per-row
           metadata; ``row_bytes``/``storage_bytes`` count it honestly.

int8 scales are SNAPPED: clamped to the fp32 normal range and truncated to
16 explicit mantissa bits (17 significant). Payloads are in [-127, 127]
(7 significant bits), so every dequant product ``payload * scale`` has at
most 24 significant bits — EXACT in fp32. This is what makes the
xla/pallas per-precision bit-parity compiler-proof: XLA freely contracts
``acc += payload * scale`` into an FMA (it does, even across
``optimization_barrier`` on CPU), but an FMA of an exact product rounds
identically to mul-then-add, so contraction can no longer split the two
kernel paths. The snap costs < 2^-16 relative scale error — noise next to
int8's 2^-8 quantization step.

The *slot* multiplier below intentionally counts row payload only
({fp32: 1, fp16: 2, int8: 4} rows per fp32-row budget); the scale metadata
(~``4/dim`` relative) is reported by the byte-accounting helpers but not
credited against the nominal budget — capacity claims stay conservative.

Everything here is shared verbatim by the ``kernel="xla"`` and
``kernel="pallas"`` paths (host numpy on the collect side, jnp epilogue on
the update side), so per-precision bit-parity between the two kernels never
depends on this module agreeing with itself.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

PRECISIONS = ("fp32", "fp16", "int8")
ROUNDINGS = ("nearest", "stochastic")

#: rows held per fp32-row of byte budget (payload bytes only; see module doc)
SLOT_MULTIPLIER = {"fp32": 1, "fp16": 2, "int8": 4}

_INT8_MAX = 127.0
_F16_MAX = 65504.0
# f32 has 23 mantissa bits, f16 has 10: stochastic rounding to f16 adds
# U[0, 2^13) to the low bits then truncates them.
_F16_DROP_BITS = 13
# int8 scale snap (see module doc): keep 16 explicit mantissa bits so the
# dequant product payload*scale is exact in fp32; clamp out of the
# subnormal range so the product's exactness argument holds everywhere.
_SCALE_DROP_BITS = 23 - 16
_SCALE_MASK = np.uint32((0xFFFFFFFF >> _SCALE_DROP_BITS) << _SCALE_DROP_BITS)
_F32_MIN_NORMAL = np.float32(2.0 ** -126)


def check_precision(precision: str) -> str:
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}"
        )
    return precision


def check_rounding(rounding: str) -> str:
    if rounding not in ROUNDINGS:
        raise ValueError(
            f"rounding must be one of {ROUNDINGS}, got {rounding!r}"
        )
    return rounding


class QuantStorage(NamedTuple):
    """int8 scratchpad storage: row payload + per-row fp32 scale column.

    A NamedTuple so it is a jax pytree — it flows through jit/donate and
    ``jax.block_until_ready`` like the plain-array storages do.
    """

    data: jax.Array   # (num_slots, dim) int8
    scale: jax.Array  # (num_slots, 1) fp32


#: a scratchpad storage operand: plain rows, or int8 rows + scale column
Storage = Union[jax.Array, QuantStorage]

#: a block of quantized rows in transit (h2d fill / d2h evict)
QuantRows = Tuple[np.ndarray, np.ndarray]


def row_bytes(dim: int, precision: str, itemsize: int = 4) -> int:
    """Bytes ONE row moves over a link (or occupies at rest), including the
    int8 per-row scale metadata. ``itemsize`` is the fp32-path element size
    (4 unless an experiment stores bf16 masters)."""
    check_precision(precision)
    if precision == "fp16":
        return dim * 2
    if precision == "int8":
        return dim * 1 + 4  # payload + fp32 scale
    return dim * itemsize


# --------------------------------------------------------------------------- #
# host-side (numpy) quantize/dequantize — the [Collect]/write-back halves
# --------------------------------------------------------------------------- #
def quantize_rows_np(rows: np.ndarray, precision: str):
    """Quantize a (n, dim) block of fp32 master rows for the h2d fill.

    Returns the rows unchanged for fp32, a float16 array for fp16, and an
    ``(int8 data, fp32 scale (n, 1))`` pair for int8. Deterministic
    round-to-nearest: fill quantization re-encodes the master, so there is
    no accumulated-update bias for stochastic rounding to fix.
    """
    check_precision(precision)
    if precision == "fp32":
        return rows
    rows = np.asarray(rows, dtype=np.float32)
    if precision == "fp16":
        return rows.astype(np.float16)
    absmax = np.max(np.abs(rows), axis=1, keepdims=True)
    scale = np.where(absmax > 0, absmax / _INT8_MAX, np.float32(1.0))
    scale = _snap_scale_np(scale.astype(np.float32))
    q = np.clip(np.round(rows / scale), -_INT8_MAX, _INT8_MAX)
    return q.astype(np.int8), scale


def _snap_scale_np(scale: np.ndarray) -> np.ndarray:
    """Clamp to the fp32 normal range and truncate to 16 explicit mantissa
    bits — the exact-product discipline (module doc). Rows whose absmax is
    subnormal quantize against the clamped (larger) scale, i.e. to a zero
    payload: the documented sub-1e-36 edge case."""
    s = np.maximum(scale.astype(np.float32), _F32_MIN_NORMAL)
    return (s.view(np.uint32) & _SCALE_MASK).view(np.float32)


def dequantize_rows_np(rows, precision: str) -> np.ndarray:
    """Write-back half: replica rows (as produced by ``quantize_rows_np`` or
    read back from a quantized scratchpad) -> fp32 rows for the master."""
    check_precision(precision)
    if precision == "fp32":
        return np.asarray(rows)
    if precision == "fp16":
        return np.asarray(rows, dtype=np.float16).astype(np.float32)
    data, scale = rows
    return np.asarray(data, dtype=np.float32) * np.asarray(
        scale, dtype=np.float32
    )


# --------------------------------------------------------------------------- #
# device-side (jnp) re-quantization — the in-cache update epilogue
# --------------------------------------------------------------------------- #
def _snap_scale_jnp(scale: jax.Array) -> jax.Array:
    """jnp twin of ``_snap_scale_np`` (identical bit manipulation)."""
    s = jnp.maximum(scale.astype(jnp.float32), jnp.float32(_F32_MIN_NORMAL))
    bits = jax.lax.bitcast_convert_type(s, jnp.uint32) & jnp.uint32(_SCALE_MASK)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _int8_scale(x: jax.Array) -> jax.Array:
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    return _snap_scale_jnp(
        jnp.where(absmax > 0, absmax / _INT8_MAX, jnp.float32(1.0))
    )


def quantize_int8_jnp(
    x: jax.Array, scale: jax.Array, rounding: str, key
) -> jax.Array:
    """fp32 -> int8 against a given per-row scale. ``stochastic`` uses
    ``floor(y + u)``, u ~ U[0, 1): unbiased for y within the clip range."""
    check_rounding(rounding)
    y = x.astype(jnp.float32) / scale
    if rounding == "stochastic":
        u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
        q = jnp.floor(y + u)
    else:
        q = jnp.round(y)
    return jnp.clip(q, -_INT8_MAX, _INT8_MAX).astype(jnp.int8)


def quantize_f16_jnp(x: jax.Array, rounding: str, key) -> jax.Array:
    """fp32 -> fp16. ``stochastic`` adds U[0, 2^13) to the low f32 mantissa
    bits then truncates them — unbiased for values in the f16 normal range
    (subnormal results re-round on the final cast; documented bias there is
    below one f16 subnormal ulp)."""
    check_rounding(rounding)
    x = jnp.clip(x.astype(jnp.float32), -_F16_MAX, _F16_MAX)
    if rounding == "nearest":
        return x.astype(jnp.float16)
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    noise = jax.random.bits(key, x.shape, dtype=jnp.uint32)
    noise = noise & jnp.uint32((1 << _F16_DROP_BITS) - 1)
    mask = jnp.uint32(~((1 << _F16_DROP_BITS) - 1) & 0xFFFFFFFF)
    bits = (bits + noise) & mask
    out = jax.lax.bitcast_convert_type(bits, jnp.float32).astype(jnp.float16)
    # rounding up at the very top of the f16 range can overflow to inf
    return jnp.clip(out, jnp.float16(-_F16_MAX), jnp.float16(_F16_MAX))


def requantize_update(
    storage: Storage,
    touched: jax.Array,
    delta: jax.Array,
    precision: str,
    rounding: str,
    key,
) -> Storage:
    """Apply a coalesced fp32 ``delta`` buffer to a quantized storage.

    ``touched`` is the (num_slots,) bool mask of rows the step updated;
    untouched rows are returned BIT-EXACT (the ``where`` keeps the original
    payload and scale), which is what keeps per-precision xla/pallas parity
    trivially stable. int8 rows recompute their per-row scale from the
    updated fp32 value so zero-born rows start learning and saturated rows
    re-range instead of clipping forever.
    """
    check_precision(precision)
    t = touched[:, None]
    if precision == "fp16":
        x = storage.astype(jnp.float32) + delta
        return jnp.where(t, quantize_f16_jnp(x, rounding, key), storage)
    data, scale = storage
    x = data.astype(jnp.float32) * scale + delta
    new_scale = _int8_scale(x)
    new_data = quantize_int8_jnp(x, new_scale, rounding, key)
    return QuantStorage(
        jnp.where(t, new_data, data), jnp.where(t, new_scale, scale)
    )
