"""Device-side (pure-jnp) [Plan] controller.

Functionally identical to repro.core.plan.Planner (the vectorized host/numpy
controller) but expressed as a jittable state transition, so the Plan stage
itself can run on-accelerator — useful when the host is the bottleneck (very
large mini-batches) or for TPU-side pipelining of the controller.

State is a pytree of arrays; `plan_step` is O(n_ids log n_ids + slots).
Victim selection uses a single argsort priority instead of the host
argpartition: eligible slots sorted by last_use (LRU), ineligible pushed to
+inf. Equivalence with the host planner is asserted in
tests/test_plan_jax.py for random traces.

Restriction vs the host planner: ``ids`` must be padded to a fixed per-batch
shape (jit static shapes); -1 entries are ignored. Victim counts are data-
dependent, so misses are allocated up to ``max_miss = ids.size`` slots per
step with unused allocations rolled back — the standard fixed-shape trick.
"""
from __future__ import annotations

import functools
from typing import List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


class PlanState(NamedTuple):
    hitmap: jax.Array  # (rows,) int32 id -> slot | -1
    slot_to_id: jax.Array  # (slots,) int32
    hold: jax.Array  # (slots,) uint32 shift register
    last_use: jax.Array  # (slots,) int32
    free_ptr: jax.Array  # () int32
    cycle: jax.Array  # () int32


def init_state(num_rows: int, num_slots: int) -> PlanState:
    return PlanState(
        hitmap=jnp.full((num_rows,), -1, jnp.int32),
        slot_to_id=jnp.full((num_slots,), -1, jnp.int32),
        hold=jnp.zeros((num_slots,), jnp.uint32),
        last_use=jnp.zeros((num_slots,), jnp.int32),
        free_ptr=jnp.zeros((), jnp.int32),
        cycle=jnp.zeros((), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("past_window",))
def plan_step(
    state: PlanState,
    ids: jax.Array,  # (n,) int32, -1 padded
    future_ids: jax.Array,  # (m,) int32, -1 padded (look-ahead window union)
    *,
    past_window: int = 3,
) -> Tuple[PlanState, dict]:
    """One [Plan] cycle. Returns (new_state, outputs) with fixed-shape
    outputs: slots (n,), fill_slots (n,), miss_ids (n,), evict_ids (n,)
    (-1 padded; fill/evict entries beyond the miss count are -1)."""
    n = ids.shape[0]
    slots_cap = state.slot_to_id.shape[0]
    cycle = state.cycle + 1
    hold = state.hold >> 1
    hold_bit = jnp.uint32(1 << past_window)

    valid = ids >= 0
    safe_ids = jnp.where(valid, ids, 0)

    # dedupe within the mini-batch: first occurrence wins
    sorted_ids = jnp.sort(jnp.where(valid, ids, jnp.iinfo(jnp.int32).max))
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    ) & (sorted_ids != jnp.iinfo(jnp.int32).max)
    uniq = jnp.where(is_first, sorted_ids, -1)  # (n,) unique ids, -1 padded
    uniq_valid = uniq >= 0
    uniq_safe = jnp.where(uniq_valid, uniq, 0)

    # hit/miss. Padded/inactive scatter entries use index -1 + mode="drop"
    # (writing placeholder values to index 0 would race with real writes).
    cur_slots = jnp.where(uniq_valid, state.hitmap[uniq_safe], -1)
    hit = cur_slots >= 0
    # NOTE: negative scatter indices WRAP in jax; out-of-bounds POSITIVE
    # sentinels (slots_cap / num_rows) are what mode="drop" discards.
    hit_mask = (
        jnp.zeros_like(hold, bool)
        .at[jnp.where(hit, cur_slots, slots_cap)]
        .set(True, mode="drop")
    )
    hold = jnp.where(hit_mask, hold | hold_bit, hold)
    last_use = jnp.where(hit_mask, cycle, state.last_use)

    miss = uniq_valid & ~hit  # (n,)
    miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1  # rank among misses
    n_miss = jnp.sum(miss.astype(jnp.int32))

    # future-window holds (recomputed fresh, as in the host planner)
    f_valid = future_ids >= 0
    f_slots = jnp.where(f_valid, state.hitmap[jnp.where(f_valid, future_ids, 0)], -1)
    future_held = (
        jnp.zeros((slots_cap,), bool)
        .at[jnp.where(f_slots >= 0, f_slots, slots_cap)]
        .set(True, mode="drop")
    )

    # allocation: fresh slots first, then LRU victims among eligible
    n_fresh_avail = slots_cap - state.free_ptr
    n_fresh = jnp.minimum(n_miss, n_fresh_avail)
    occupied = state.slot_to_id >= 0
    eligible = (hold == 0) & ~future_held & occupied
    # LRU priority: eligible sorted by last_use; ineligible at +inf
    prio = jnp.where(eligible, last_use, jnp.iinfo(jnp.int32).max)
    victim_order = jnp.argsort(prio)  # (slots,)
    n_evict = n_miss - n_fresh
    n_eligible = jnp.sum(eligible.astype(jnp.int32))
    ok = n_evict <= n_eligible  # enough victims? (host planner raises)

    # per-miss slot: fresh if rank < n_fresh else victim[rank - n_fresh]
    fresh_slot = state.free_ptr + miss_rank
    evict_rank = jnp.clip(miss_rank - n_fresh, 0, slots_cap - 1)
    victim_slot = victim_order[evict_rank]
    fill_slot = jnp.where(miss_rank < n_fresh, fresh_slot, victim_slot)
    fill_slot = jnp.where(miss, fill_slot, -1)

    # evicted ids (only for victim allocations)
    is_victim = miss & (miss_rank >= n_fresh)
    evict_slot_safe = jnp.where(is_victim, fill_slot, 0)
    evict_ids = jnp.where(is_victim, state.slot_to_id[evict_slot_safe], -1)

    # state updates (drop-mode scatters; evict-clear before miss-insert so a
    # row evicted and re-inserted in the same cycle keeps the new slot)
    num_rows = state.hitmap.shape[0]
    hitmap = state.hitmap.at[
        jnp.where(evict_ids >= 0, evict_ids, num_rows)
    ].set(-1, mode="drop")
    hitmap = hitmap.at[jnp.where(miss, uniq_safe, num_rows)].set(
        fill_slot, mode="drop"
    )
    slot_to_id = state.slot_to_id.at[
        jnp.where(miss, fill_slot, slots_cap)
    ].set(uniq, mode="drop")
    fill_mask = (
        jnp.zeros((slots_cap,), bool)
        .at[jnp.where(miss, fill_slot, slots_cap)]
        .set(True, mode="drop")
    )
    hold = jnp.where(fill_mask, hold | hold_bit, hold)
    last_use = jnp.where(fill_mask, cycle, last_use)

    out_slots = jnp.where(valid, hitmap[safe_ids], -1)
    new_state = PlanState(
        hitmap=hitmap,
        slot_to_id=slot_to_id,
        hold=hold,
        last_use=last_use,
        free_ptr=state.free_ptr + n_fresh,
        cycle=cycle,
    )
    outputs = {
        "slots": out_slots,
        "miss_ids": jnp.where(miss, uniq, -1),
        "fill_slots": fill_slot,
        "evict_ids": evict_ids,
        "n_hits": jnp.sum(hit.astype(jnp.int32)),
        "n_unique": jnp.sum(uniq_valid.astype(jnp.int32)),
        "ok": ok,
    }
    return new_state, outputs


# ---------------------------------------------------------------------------
# Multi-table (TableGroup) wrapper: per-table device planners over one fused
# slot space. Each table's misses allocate only from its own slot budget —
# the device analog of the host Planner's slot_ranges. States are a list (one
# PlanState per table, jit-cached per shape); outputs are offset into GLOBAL
# slot/row coordinates so [Collect]/[Insert]/[Train] address the fused
# Storage array directly.
# ---------------------------------------------------------------------------


def init_group_states(group, budgets: Sequence[int]) -> List[PlanState]:
    """One PlanState per table of a TableGroup, sized by its slot budget."""
    assert len(budgets) == group.num_tables, (len(budgets), group.num_tables)
    return [
        init_state(spec.rows, int(b)) for spec, b in zip(group.tables, budgets)
    ]


def plan_group_step(
    states: List[PlanState],
    group,
    per_table_ids: Sequence[jax.Array],  # local ids per table, -1 padded
    per_table_future: Sequence[jax.Array],  # local look-ahead union per table
    *,
    past_window: int = 3,
) -> Tuple[List[PlanState], List[dict]]:
    """One fused [Plan] cycle over every table. Returns per-table outputs
    with ``slots``/``fill_slots`` offset by the table's slot-range start and
    ``miss_ids``/``evict_ids`` offset into the fused row space (-1 padding
    preserved)."""
    slot_lo = 0
    new_states, outs = [], []
    for t, state in enumerate(states):
        st, out = plan_step(
            state,
            jnp.asarray(per_table_ids[t], jnp.int32),
            jnp.asarray(per_table_future[t], jnp.int32),
            past_window=past_window,
        )
        row_off = jnp.int32(group.offsets[t])
        off = {
            "slots": jnp.where(out["slots"] >= 0, out["slots"] + slot_lo, -1),
            "fill_slots": jnp.where(
                out["fill_slots"] >= 0, out["fill_slots"] + slot_lo, -1
            ),
            "miss_ids": jnp.where(
                out["miss_ids"] >= 0, out["miss_ids"] + row_off, -1
            ),
            "evict_ids": jnp.where(
                out["evict_ids"] >= 0, out["evict_ids"] + row_off, -1
            ),
            "n_hits": out["n_hits"],
            "n_unique": out["n_unique"],
            "ok": out["ok"],
        }
        new_states.append(st)
        outs.append(off)
        slot_lo += state.slot_to_id.shape[0]
    return new_states, outs
