"""Device-side (pure-jnp) [Plan] controller.

Functionally identical to repro.core.plan.Planner (the vectorized host/numpy
controller) but expressed as a jittable state transition, so the Plan stage
itself can run on-accelerator — useful when the host is the bottleneck (very
large mini-batches) or for TPU-side pipelining of the controller.

State is a pytree of arrays; `plan_step` is O(n_ids log n_ids + slots).
Victim selection uses a single argsort priority instead of the host
argpartition: eligible slots sorted by last_use (LRU), ineligible pushed to
+inf. Equivalence with the host planner is asserted in
tests/test_plan_jax.py for random traces.

Restriction vs the host planner: ``ids`` must be padded to a fixed per-batch
shape (jit static shapes); -1 entries are ignored. Victim counts are data-
dependent, so misses are allocated up to ``max_miss = ids.size`` slots per
step with unused allocations rolled back — the standard fixed-shape trick.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import PAD_FLOOR, PinnedCache, pad_len


class PlanState(NamedTuple):
    hitmap: jax.Array  # (rows,) int32 id -> slot | -1
    slot_to_id: jax.Array  # (slots,) int32
    hold: jax.Array  # (slots,) uint32 shift register
    last_use: jax.Array  # (slots,) int32
    free_ptr: jax.Array  # () int32
    cycle: jax.Array  # () int32


def init_state(num_rows: int, num_slots: int) -> PlanState:
    return PlanState(
        hitmap=jnp.full((num_rows,), -1, jnp.int32),
        slot_to_id=jnp.full((num_slots,), -1, jnp.int32),
        hold=jnp.zeros((num_slots,), jnp.uint32),
        last_use=jnp.zeros((num_slots,), jnp.int32),
        free_ptr=jnp.zeros((), jnp.int32),
        cycle=jnp.zeros((), jnp.int32),
    )


@functools.partial(jax.jit, static_argnames=("past_window",))
def plan_step(
    state: PlanState,
    ids: jax.Array,  # (n,) int32, -1 padded
    future_ids: jax.Array,  # (m,) int32, -1 padded (look-ahead window union)
    *,
    past_window: int = 3,
) -> Tuple[PlanState, dict]:
    """One [Plan] cycle. Returns (new_state, outputs) with fixed-shape
    outputs: slots (n,), fill_slots (n,), miss_ids (n,), evict_ids (n,)
    (-1 padded; fill/evict entries beyond the miss count are -1)."""
    n = ids.shape[0]
    slots_cap = state.slot_to_id.shape[0]
    cycle = state.cycle + 1
    hold = state.hold >> 1
    hold_bit = jnp.uint32(1 << past_window)

    valid = ids >= 0
    safe_ids = jnp.where(valid, ids, 0)

    # dedupe within the mini-batch: first occurrence wins
    sorted_ids = jnp.sort(jnp.where(valid, ids, jnp.iinfo(jnp.int32).max))
    is_first = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_ids[1:] != sorted_ids[:-1]]
    ) & (sorted_ids != jnp.iinfo(jnp.int32).max)
    uniq = jnp.where(is_first, sorted_ids, -1)  # (n,) unique ids, -1 padded
    uniq_valid = uniq >= 0
    uniq_safe = jnp.where(uniq_valid, uniq, 0)

    # hit/miss. Padded/inactive scatter entries use index -1 + mode="drop"
    # (writing placeholder values to index 0 would race with real writes).
    cur_slots = jnp.where(uniq_valid, state.hitmap[uniq_safe], -1)
    hit = cur_slots >= 0
    # NOTE: negative scatter indices WRAP in jax; out-of-bounds POSITIVE
    # sentinels (slots_cap / num_rows) are what mode="drop" discards.
    hit_mask = (
        jnp.zeros_like(hold, bool)
        .at[jnp.where(hit, cur_slots, slots_cap)]
        .set(True, mode="drop")
    )
    hold = jnp.where(hit_mask, hold | hold_bit, hold)
    last_use = jnp.where(hit_mask, cycle, state.last_use)

    miss = uniq_valid & ~hit  # (n,)
    miss_rank = jnp.cumsum(miss.astype(jnp.int32)) - 1  # rank among misses
    n_miss = jnp.sum(miss.astype(jnp.int32))

    # future-window holds (recomputed fresh, as in the host planner)
    f_valid = future_ids >= 0
    f_slots = jnp.where(f_valid, state.hitmap[jnp.where(f_valid, future_ids, 0)], -1)
    future_held = (
        jnp.zeros((slots_cap,), bool)
        .at[jnp.where(f_slots >= 0, f_slots, slots_cap)]
        .set(True, mode="drop")
    )

    # allocation: fresh slots first, then LRU victims among eligible
    n_fresh_avail = slots_cap - state.free_ptr
    n_fresh = jnp.minimum(n_miss, n_fresh_avail)
    occupied = state.slot_to_id >= 0
    eligible = (hold == 0) & ~future_held & occupied
    # LRU priority: eligible sorted by last_use; ineligible at +inf.
    # jnp.argsort is stable, so ties in last_use resolve by slot index —
    # exactly the host planner's stable-argsort victim order.
    prio = jnp.where(eligible, last_use, jnp.iinfo(jnp.int32).max)
    victim_order = jnp.argsort(prio)  # (slots,)
    n_evict = n_miss - n_fresh
    n_eligible = jnp.sum(eligible.astype(jnp.int32))
    ok = n_evict <= n_eligible  # enough victims? (host planner raises)

    # per-miss slot: fresh if rank < n_fresh else victim[rank - n_fresh]
    fresh_slot = state.free_ptr + miss_rank
    evict_rank = jnp.clip(miss_rank - n_fresh, 0, slots_cap - 1)
    victim_slot = victim_order[evict_rank]
    fill_slot = jnp.where(miss_rank < n_fresh, fresh_slot, victim_slot)
    fill_slot = jnp.where(miss, fill_slot, -1)

    # evicted ids (only for victim allocations)
    is_victim = miss & (miss_rank >= n_fresh)
    evict_slot_safe = jnp.where(is_victim, fill_slot, 0)
    evict_ids = jnp.where(is_victim, state.slot_to_id[evict_slot_safe], -1)

    # state updates (drop-mode scatters; evict-clear before miss-insert so a
    # row evicted and re-inserted in the same cycle keeps the new slot)
    num_rows = state.hitmap.shape[0]
    hitmap = state.hitmap.at[
        jnp.where(evict_ids >= 0, evict_ids, num_rows)
    ].set(-1, mode="drop")
    hitmap = hitmap.at[jnp.where(miss, uniq_safe, num_rows)].set(
        fill_slot, mode="drop"
    )
    slot_to_id = state.slot_to_id.at[
        jnp.where(miss, fill_slot, slots_cap)
    ].set(uniq, mode="drop")
    fill_mask = (
        jnp.zeros((slots_cap,), bool)
        .at[jnp.where(miss, fill_slot, slots_cap)]
        .set(True, mode="drop")
    )
    hold = jnp.where(fill_mask, hold | hold_bit, hold)
    last_use = jnp.where(fill_mask, cycle, last_use)

    out_slots = jnp.where(valid, hitmap[safe_ids], -1)
    new_state = PlanState(
        hitmap=hitmap,
        slot_to_id=slot_to_id,
        hold=hold,
        last_use=last_use,
        free_ptr=state.free_ptr + n_fresh,
        cycle=cycle,
    )
    outputs = {
        "slots": out_slots,
        "miss_ids": jnp.where(miss, uniq, -1),
        "fill_slots": fill_slot,
        "evict_ids": evict_ids,
        "n_hits": jnp.sum(hit.astype(jnp.int32)),
        "n_unique": jnp.sum(uniq_valid.astype(jnp.int32)),
        "ok": ok,
        # overflow diagnostics (host side surfaces these in the same error
        # the host Planner raises when a cycle cannot find enough victims)
        "n_evict": jnp.maximum(n_evict, 0),
        "n_eligible": n_eligible,
    }
    return new_state, outputs


@functools.partial(jax.jit, static_argnames=("past_window",))
def plan_window(
    state: PlanState,
    ids_steps: jax.Array,  # (W, n) int32, -1 padded per step
    future_steps: jax.Array,  # (W, m) int32, -1 padded per step
    *,
    past_window: int = 3,
) -> Tuple[PlanState, dict]:
    """Batched multi-step [Plan]: run ``W`` consecutive cycles in ONE device
    dispatch via ``lax.scan`` — the look-ahead window (or a whole trace
    prefix) planned without returning to the host between cycles. Outputs
    are the per-step :func:`plan_step` dicts stacked on a leading ``W`` axis;
    equivalence with ``W`` sequential ``plan_step`` calls is asserted in
    tests/test_plan_jax.py."""

    def body(st, xs):
        ids, fut = xs
        st, out = plan_step(st, ids, fut, past_window=past_window)
        return st, out

    return jax.lax.scan(body, state, (ids_steps, future_steps))


# ---------------------------------------------------------------------------
# Multi-table (TableGroup) wrapper: per-table device planners over one fused
# slot space. Each table's misses allocate only from its own slot budget —
# the device analog of the host Planner's slot_ranges. States are a list (one
# PlanState per table, jit-cached per shape); outputs are offset into GLOBAL
# slot/row coordinates so [Collect]/[Insert]/[Train] address the fused
# Storage array directly.
# ---------------------------------------------------------------------------


def init_group_states(group, budgets: Sequence[int]) -> List[PlanState]:
    """One PlanState per table of a TableGroup, sized by its slot budget."""
    assert len(budgets) == group.num_tables, (len(budgets), group.num_tables)
    return [
        init_state(spec.rows, int(b)) for spec, b in zip(group.tables, budgets)
    ]


def plan_group_step(
    states: List[PlanState],
    group,
    per_table_ids: Sequence[jax.Array],  # local ids per table, -1 padded
    per_table_future: Sequence[jax.Array],  # local look-ahead union per table
    *,
    past_window: int = 3,
) -> Tuple[List[PlanState], List[dict]]:
    """One fused [Plan] cycle over every table. ``group`` is a TableGroup or
    any sequence of fused row offsets (len num_tables + 1). Returns
    per-table outputs with ``slots``/``fill_slots`` offset by the table's
    slot-range start and ``miss_ids``/``evict_ids`` offset into the fused
    row space (-1 padding preserved)."""
    offsets = getattr(group, "offsets", group)
    slot_lo = 0
    new_states, outs = [], []
    for t, state in enumerate(states):
        st, out = plan_step(
            state,
            jnp.asarray(per_table_ids[t], jnp.int32),
            jnp.asarray(per_table_future[t], jnp.int32),
            past_window=past_window,
        )
        row_off = jnp.int32(offsets[t])
        off = {
            "slots": jnp.where(out["slots"] >= 0, out["slots"] + slot_lo, -1),
            "fill_slots": jnp.where(
                out["fill_slots"] >= 0, out["fill_slots"] + slot_lo, -1
            ),
            "miss_ids": jnp.where(
                out["miss_ids"] >= 0, out["miss_ids"] + row_off, -1
            ),
            "evict_ids": jnp.where(
                out["evict_ids"] >= 0, out["evict_ids"] + row_off, -1
            ),
            "n_hits": out["n_hits"],
            "n_unique": out["n_unique"],
            "ok": out["ok"],
            "n_evict": out["n_evict"],
            "n_eligible": out["n_eligible"],
        }
        new_states.append(st)
        outs.append(off)
        slot_lo += state.slot_to_id.shape[0]
    return new_states, outs


# ---------------------------------------------------------------------------
# Device-resident [Plan] runtime wrapper: the drop-in Planner replacement the
# pipeline selects with ``planner="device"``. PlanState lives on-accelerator;
# each plan() uploads RAW ids (h2d) and runs plan_step / plan_group_step on
# device — the dense id->slot translate never touches the host and the
# translated ``slots`` operand never crosses the PCIe link. Only the small
# miss/evict/fill vectors sync back (lazily, overlappable with [Train]) for
# the [Collect]/[Insert] host-table halves.
# ---------------------------------------------------------------------------


_STATE_FIELDS = ("hitmap", "slot_to_id", "hold", "last_use", "free_ptr", "cycle")


def state_to_host(state: PlanState) -> Dict[str, np.ndarray]:
    """One d2h snapshot of a PlanState (checkpointing)."""
    host = jax.device_get(state)
    return {f: np.asarray(getattr(host, f)) for f in _STATE_FIELDS}


def state_from_host(arrays: Dict[str, np.ndarray]) -> PlanState:
    """Rebuild a device-resident PlanState from a host snapshot."""
    dtypes = dict(
        hitmap=jnp.int32, slot_to_id=jnp.int32, hold=jnp.uint32,
        last_use=jnp.int32, free_ptr=jnp.int32, cycle=jnp.int32,
    )
    return PlanState(
        **{
            f: jax.device_put(jnp.asarray(arrays[f], dtypes[f]))
            for f in _STATE_FIELDS
        }
    )


class DevicePlanResult:
    """[Plan] outputs of one cycle from the device planner.

    ``slots`` is the DEVICE-resident dense id->slot translation (same shape
    as the input ids) — the [Train]/fused dispatch consumes it directly, so
    no slot operand is ever h2d'd. The host-facing fields (``miss_ids``,
    ``fill_slots``, ``evict_slots``, ``evict_ids``, counts) materialize
    lazily on first access via ONE d2h of the fixed-shape outputs —
    ``start_materialize`` moves that sync onto a background worker so it
    overlaps the [Train] dispatch (the PR-4 executor pattern). Field order
    and dtypes are element-for-element identical to the host
    :class:`~repro.core.plan.PlanResult`."""

    __slots__ = (
        "step", "slots", "_payload", "_slot_sizes", "_num_slots",
        "_window_desc", "_future", "_host", "hits_by_table",
        "misses_by_table", "miss_ids", "fill_slots", "evict_slots",
        "evict_ids", "n_unique", "n_hits",
    )

    def __init__(self, step, slots, payload, slot_sizes, num_slots, window_desc):
        self.step = step
        self.slots = slots  # device array, input-ids shape
        self._payload = payload  # per-table device dicts (no dense slots)
        self._slot_sizes = slot_sizes  # per-table budget (error messages)
        self._num_slots = num_slots
        self._window_desc = window_desc  # "past+1+future" (error messages)
        self._future = None
        self._host = False

    def start_materialize(self, pool, tracer=None) -> None:
        """Kick the d2h of the host-facing outputs onto ``pool`` (the
        pipeline's d2h worker) so it overlaps [Train]. With a tracer the
        device_get is spanned on the worker thread that executes it."""
        if not self._host and self._future is None:
            fn = jax.device_get
            if tracer is not None:
                fn = tracer.wrap("plan.materialize", fn, cat="d2h")
            self._future = pool.submit(fn, self._payload)

    def _materialize(self):
        if self._host:
            return
        outs = (
            self._future.result()
            if self._future is not None
            else jax.device_get(self._payload)
        )
        self._future = None
        miss_p, fill_p, ev_slot_p, ev_id_p = [], [], [], []
        hits_t, uniq_t = [], []
        for t, o in enumerate(outs):
            if not bool(o["ok"]):
                # same failure, same words as the host Planner's raise
                raise RuntimeError(
                    f"scratchpad too small: need {int(o['n_evict'])} victims, "
                    f"only {int(o['n_eligible'])} evictable (table {t}: "
                    f"slots={self._slot_sizes[t]} of {self._num_slots}, "
                    f"window={self._window_desc}); size the Storage array "
                    "for the worst-case window working set (paper §VI-D)."
                )
            miss = np.asarray(o["miss_ids"])
            fill = np.asarray(o["fill_slots"])
            ev = np.asarray(o["evict_ids"])
            m = miss >= 0
            miss_p.append(miss[m])
            fill_p.append(fill[m])
            vm = ev >= 0
            ev_id_p.append(ev[vm])
            ev_slot_p.append(fill[vm])  # a victim's fill slot IS its slot
            hits_t.append(int(o["n_hits"]))
            uniq_t.append(int(o["n_unique"]))
        self.miss_ids = np.concatenate(miss_p) if miss_p else np.empty(0, np.int32)
        self.fill_slots = np.concatenate(fill_p) if fill_p else np.empty(0, np.int32)
        self.evict_slots = (
            np.concatenate(ev_slot_p) if ev_slot_p else np.empty(0, np.int32)
        )
        self.evict_ids = (
            np.concatenate(ev_id_p) if ev_id_p else np.empty(0, np.int32)
        )
        self.n_hits = sum(hits_t)
        self.n_unique = sum(uniq_t)
        if len(outs) > 1:
            self.hits_by_table = np.asarray(hits_t, np.int64)
            self.misses_by_table = np.asarray(
                [u - h for u, h in zip(uniq_t, hits_t)], np.int64
            )
        else:
            self.hits_by_table = self.misses_by_table = None
        self._host = True

    def __getattr__(self, name):
        # first touch of any host-facing field triggers the one d2h sync
        if name in (
            "miss_ids", "fill_slots", "evict_slots", "evict_ids",
            "n_unique", "n_hits", "hits_by_table", "misses_by_table",
        ):
            self._materialize()
            return object.__getattribute__(self, name)
        raise AttributeError(name)


class DevicePlanner:
    """Device-resident [Plan] controller with the host Planner's interface.

    Bit-identical to ``Planner(policy="lru")`` on every output (asserted in
    tests/test_device_planner.py); restrictions vs the host controller:

    * LRU only (the jittable transition has no RNG / use-count path);
    * fixed-shape dispatches: ids are padded to a monotone per-planner
      bucket, so a stream of varying batch sizes compiles O(1) executables;
    * multi-table (``slot_ranges``) planning requires the standard
      ``(B, num_tables, L)`` id layout where ``ids[:, t, :]`` holds table
      t's global ids — every generator/trace in this repo emits it (checked
      on the first batch).
    """

    def __init__(
        self,
        num_rows: int,
        num_slots: int,
        *,
        past_window: int = 3,
        future_window: int = 2,
        policy: str = "lru",
        row_offsets: Optional[Sequence[int]] = None,
        slot_ranges: Optional[Sequence[Tuple[int, int]]] = None,
        pad_buckets: Optional[Sequence[int]] = None,
    ):
        if policy != "lru":
            raise ValueError(
                f"device planner supports policy='lru' only (got {policy!r}); "
                "use planner='host' for random/lfu replacement"
            )
        if int(num_rows) > np.iinfo(np.int32).max or int(num_slots) > np.iinfo(
            np.int32
        ).max:
            raise ValueError(
                f"int32 index path: num_rows={num_rows} / num_slots="
                f"{num_slots} must fit in int32 (< 2**31)"
            )
        self.num_rows = int(num_rows)
        self.num_slots = int(num_slots)
        self.past_window = int(past_window)
        self.future_window = int(future_window)
        self.policy = policy
        self.row_offsets = (
            np.asarray(row_offsets, dtype=np.int64)
            if row_offsets is not None
            else np.array([0, self.num_rows], dtype=np.int64)
        )
        self.slot_ranges = (
            [(int(lo), int(hi)) for lo, hi in slot_ranges]
            if slot_ranges is not None
            else [(0, self.num_slots)]
        )
        self.num_tables = len(self.slot_ranges)
        if len(self.row_offsets) != self.num_tables + 1:
            raise ValueError(
                f"row_offsets has {len(self.row_offsets) - 1} tables, "
                f"slot_ranges has {self.num_tables}"
            )
        self._budgets = [hi - lo for lo, hi in self.slot_ranges]
        self._table_rows = np.diff(self.row_offsets)
        self._states: List[PlanState] = [
            init_state(int(r), int(b))
            for r, b in zip(self._table_rows, self._budgets)
        ]
        self._cycle = 0  # host-side mirror of the device cycle counters
        self._pad_buckets = tuple(sorted(pad_buckets)) if pad_buckets else None
        # monotone pad lengths: one warm executable per planner even when
        # the stream's batch sizes vary (sharded bucketing, drain cycles)
        self._ids_pad = 0
        self._fut_pad = 0
        self._validated = False
        self._prep = PinnedCache(4 * (self.future_window + 2))
        self._empty_future = jnp.full((PAD_FLOOR,), -1, jnp.int32)

    # -- per-batch host prep (id()-memoized across look-ahead sightings) ----
    def _prep_single(self, ids) -> np.ndarray:
        flat = np.asarray(ids, dtype=np.int32).ravel()
        if not self._validated and flat.size:
            if int(flat.min()) < 0 or int(flat.max()) >= self.num_rows:
                raise ValueError(
                    f"ids outside [0, {self.num_rows}) — the device planner "
                    "gathers with clamped indices and would diverge silently"
                )
        return flat

    def _prep_tables(self, ids) -> np.ndarray:
        arr = np.asarray(ids, dtype=np.int64)
        T = self.num_tables
        if arr.ndim != 3 or arr.shape[1] != T:
            raise ValueError(
                f"device planner with {T} tables needs (B, {T}, L) ids "
                f"(got shape {arr.shape}); use planner='host' for "
                "non-standard id layouts"
            )
        loc = (arr - self.row_offsets[:-1][None, :, None]).transpose(1, 0, 2)
        loc = np.ascontiguousarray(loc.reshape(T, -1)).astype(np.int32)
        if not self._validated:
            for t in range(T):
                if loc[t].size and (
                    int(loc[t].min()) < 0
                    or int(loc[t].max()) >= int(self._table_rows[t])
                ):
                    raise ValueError(
                        f"ids[:, {t}, :] outside table {t}'s row range — the "
                        "device planner requires the standard (B, T, L) "
                        "layout; use planner='host' otherwise"
                    )
        return loc

    def _pad_to(self, n: int, attr: str) -> int:
        p = pad_len(n, self._pad_buckets)
        p = max(p, getattr(self, attr))
        setattr(self, attr, p)
        return p

    # -- the [Plan] cycle ----------------------------------------------------
    def plan(self, ids, future_batches=None) -> DevicePlanResult:
        self._cycle += 1
        window_desc = f"{self.past_window}+1+{self.future_window}"
        futures = (
            list(future_batches[: self.future_window])
            if self.future_window and future_batches
            else []
        )
        if self.num_tables == 1:
            flat = self._prep.get(ids, self._prep_single)
            self._validated = True
            n = flat.size
            p = self._pad_to(n, "_ids_pad")
            up = np.full(p, -1, np.int32)
            up[:n] = flat
            dev_ids = jax.device_put(up)  # raw ids h2d — the only operand
            if futures:
                parts = [self._prep.get(fb, self._prep_single) for fb in futures]
                total = sum(x.size for x in parts)
                fp = self._pad_to(total, "_fut_pad")
                fut = np.full(fp, -1, np.int32)
                o = 0
                for x in parts:
                    fut[o : o + x.size] = x
                    o += x.size
                dev_fut = jax.device_put(fut)
            else:
                dev_fut = self._empty_future
            self._states[0], out = plan_step(
                self._states[0], dev_ids, dev_fut, past_window=self.past_window
            )
            shape = np.asarray(ids).shape
            slots = out["slots"][:n].reshape(shape)
            payload = [{k: out[k] for k in out if k != "slots"}]
        else:
            blk = self._prep.get(ids, self._prep_tables)
            self._validated = True
            T, width = blk.shape
            p = self._pad_to(width, "_ids_pad")
            if p != width:  # monotone bucket: O(1) executables per table
                up = np.full((T, p), -1, np.int32)
                up[:, :width] = blk
            else:
                up = blk
            dev_blk = jax.device_put(up)
            if futures:
                fparts = [self._prep.get(fb, self._prep_tables) for fb in futures]
                total = sum(f.shape[1] for f in fparts)
                fp = self._pad_to(total, "_fut_pad")
                fut = np.full((T, fp), -1, np.int32)
                o = 0
                for f in fparts:
                    fut[:, o : o + f.shape[1]] = f
                    o += f.shape[1]
                dev_fut_blk = jax.device_put(fut)
                per_fut = [dev_fut_blk[t] for t in range(T)]
            else:
                per_fut = [self._empty_future] * T
            self._states, outs = plan_group_step(
                self._states,
                self.row_offsets,
                [dev_blk[t] for t in range(T)],
                per_fut,
                past_window=self.past_window,
            )
            B, _, L = np.asarray(ids).shape
            slots = jnp.stack(
                [o["slots"][:width].reshape(B, L) for o in outs], axis=1
            )  # (B, T, L) global slots, device-resident
            payload = [{k: o[k] for k in o if k != "slots"} for o in outs]
        return DevicePlanResult(
            self._cycle, slots, payload, self._budgets, self.num_slots,
            window_desc,
        )

    # -- stats / state the runtimes read ------------------------------------
    @property
    def occupancy(self) -> int:
        return int(sum(int(jnp.sum(s.slot_to_id >= 0)) for s in self._states))

    @property
    def slot_to_id(self) -> np.ndarray:
        """Fused-coordinate slot->row map (one d2h per call): slot indices
        global, row ids global — what ``flush_to_host`` walks."""
        out = np.full(self.num_slots, -1, np.int32)
        for t, st in enumerate(self._states):
            lo, hi = self.slot_ranges[t]
            s2i = np.asarray(st.slot_to_id)
            m = s2i >= 0
            seg = out[lo:hi]
            seg[m] = (s2i[m].astype(np.int64) + self.row_offsets[t]).astype(
                np.int32
            )
        return out

    # -- checkpoint / resume -------------------------------------------------
    def state_dict(self) -> Dict[str, np.ndarray]:
        out: Dict[str, np.ndarray] = {}
        for t, st in enumerate(self._states):
            for k, v in state_to_host(st).items():
                out[f"t{t}_{k}"] = v
        return out

    def load_state_dict(self, st: Dict[str, np.ndarray]) -> None:
        states = []
        for t in range(self.num_tables):
            try:
                arrays = {f: st[f"t{t}_{f}"] for f in _STATE_FIELDS}
            except KeyError as e:
                raise ValueError(
                    "incompatible device-planner checkpoint: missing "
                    f"{e.args[0]!r} (host-planner checkpoints do not load "
                    "into planner='device' runs and vice versa)"
                ) from None
            states.append(state_from_host(arrays))
        self._states = states
        self._cycle = int(np.asarray(st["t0_cycle"]))
        self._prep.clear()
