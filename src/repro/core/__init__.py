# The paper's primary contribution: ScratchPipe — a look-forward, always-hit
# embedding cache runtime (Plan/Collect/Exchange/Insert/Train pipeline),
# generalized over multi-table embedding models via TableGroup and unified
# behind the EmbeddingCacheRuntime registry.
from repro.core.host_table import HostEmbeddingTable, HostTraffic  # noqa: F401
from repro.core.pipeline import ScratchPipe, StepStats  # noqa: F401
from repro.core.plan import Planner, PlanResult  # noqa: F401
from repro.core.runtime import (  # noqa: F401
    EmbeddingCacheRuntime,
    available_runtimes,
    make_runtime,
    register_runtime,
)
from repro.core.sharded_pipeline import ShardedScratchPipe  # noqa: F401
from repro.core.static_cache import (  # noqa: F401
    NoCacheBaseline,
    StaticCacheBaseline,
)
from repro.core.table_group import TableGroup, TableSpec, single_table  # noqa: F401
