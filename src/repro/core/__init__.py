# The paper's primary contribution: ScratchPipe — a look-forward, always-hit
# embedding cache runtime (Plan/Collect/Exchange/Insert/Train pipeline).
from repro.core.host_table import HostEmbeddingTable, HostTraffic  # noqa: F401
from repro.core.pipeline import ScratchPipe, StepStats  # noqa: F401
from repro.core.plan import Planner, PlanResult  # noqa: F401
from repro.core.static_cache import (  # noqa: F401
    NoCacheBaseline,
    StaticCacheBaseline,
)
