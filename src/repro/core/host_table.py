"""Capacity-tier ("CPU DRAM") embedding table.

The paper keeps the full embedding tables in slow/large CPU memory; gathers
and scatters against it are the bottleneck ScratchPipe removes from the
critical path. Byte counters feed the calibrated bandwidth model used by the
paper-figure benchmarks (this container cannot measure a real two-tier
memory hierarchy).

Integrity guard (opt-in): ``enable_guard()`` keeps a per-row XOR checksum
of the table. Every ``gather`` verifies the rows it reads and every
``scatter``/``scatter_add_grad`` re-sums the rows it writes, so a bit flip
in host DRAM (or a stray write through the raw ``data`` buffer) raises
``RowCorruptionError`` at the first read instead of silently training on
garbage. Recovery is either targeted (``repair_rows`` re-fetches the rows
from a master copy) or global (checkpoint restore + fast-forward — see
``repro.runtime.fault_tolerance``). The guard is off by default: the
checksum pass costs a full-row read per gather/scatter.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


class RowCorruptionError(RuntimeError):
    """One or more host-table rows no longer match their checksums."""

    def __init__(self, rows: Sequence[int]):
        self.rows = [int(r) for r in rows]
        super().__init__(
            f"host-table row corruption detected in {len(self.rows)} row(s): "
            f"{self.rows[:8]}{'…' if len(self.rows) > 8 else ''}"
        )


@dataclasses.dataclass
class HostTraffic:
    """Byte counters for one memory tier / link."""

    read: int = 0
    written: int = 0

    def reset(self):
        self.read = 0
        self.written = 0

    @property
    def total(self) -> int:
        return self.read + self.written


class HostEmbeddingTable:
    """rows x dim fp32 table resident in host memory (numpy).

    For multi-table models (DLRM) the tables are flattened into one global
    row space (global_id = table * rows_per_table + id) — this matches the
    paper's per-table cache managers (ranges never interleave) while keeping
    one vectorized controller.
    """

    def __init__(
        self,
        rows: int,
        dim: int,
        *,
        seed: int = 0,
        dtype=np.float32,
        data=None,
        guard: bool = False,
    ):
        if data is not None:
            assert data.shape == (rows, dim)
            self.data = data
        else:
            rng = np.random.default_rng(seed)
            scale = 1.0 / np.sqrt(dim)
            self.data = (rng.standard_normal((rows, dim)) * scale).astype(dtype)
        self.traffic = HostTraffic()
        self._sums: Optional[np.ndarray] = None
        if guard:
            self.enable_guard()

    @property
    def rows(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def row_bytes(self) -> int:
        return self.data.shape[1] * self.data.dtype.itemsize

    # -- integrity guard ----------------------------------------------------
    @property
    def guarded(self) -> bool:
        return self._sums is not None

    def _row_sums(self, rows: np.ndarray) -> np.ndarray:
        """Vectorized per-row XOR fold of the raw row bytes. A single flipped
        byte always changes the fold (x ^ y != 0 for x != y at the same
        position), which is the corruption model the chaos harness injects."""
        a = np.ascontiguousarray(rows)
        if a.ndim == 1:
            a = a[None, :]
        nbytes = a.shape[1] * a.itemsize
        if nbytes % 4 == 0:
            v = a.view(np.uint32).reshape(a.shape[0], -1)
        else:
            v = a.view(np.uint8).reshape(a.shape[0], -1)
        return np.bitwise_xor.reduce(v.astype(np.uint32, copy=False), axis=1)

    def enable_guard(self) -> None:
        """Compute checksums for the whole table and start verifying."""
        self._sums = self._row_sums(self.data)

    def reguard(self, ids: Optional[np.ndarray] = None) -> None:
        """Recompute checksums (all rows, or just ``ids``) after a legitimate
        out-of-band write — e.g. an in-place checkpoint load."""
        if self._sums is None:
            return
        if ids is None:
            self._sums = self._row_sums(self.data)
        else:
            u = np.unique(np.asarray(ids).ravel())
            self._sums[u] = self._row_sums(self.data[u])

    def verify(self, ids: Optional[np.ndarray] = None) -> None:
        """Raise :class:`RowCorruptionError` if any (given) row's bytes no
        longer match its checksum. No-op when the guard is off."""
        if self._sums is None:
            return
        if ids is None:
            bad = np.flatnonzero(self._row_sums(self.data) != self._sums)
        else:
            u = np.unique(np.asarray(ids).ravel())
            if u.size == 0:
                return
            bad = u[self._row_sums(self.data[u]) != self._sums[u]]
        if bad.size:
            raise RowCorruptionError(bad.tolist())

    def repair_rows(self, ids: np.ndarray, rows: np.ndarray) -> None:
        """Targeted recovery: overwrite corrupted rows with known-good master
        values (e.g. from a replica or the latest checkpoint) and re-sum."""
        ids = np.asarray(ids).ravel()
        self.traffic.written += ids.size * self.row_bytes
        self.data[ids] = rows
        self.reguard(ids)

    # -- access path --------------------------------------------------------
    def gather(self, ids: np.ndarray) -> np.ndarray:
        """[Collect]: read missed rows from the capacity tier."""
        if self._sums is not None:
            self.verify(ids)
        self.traffic.read += ids.size * self.row_bytes
        return self.data[ids]

    def scatter(self, ids: np.ndarray, values: np.ndarray) -> None:
        """[Insert]: write evicted (dirty, trained) rows back."""
        self.traffic.written += ids.size * self.row_bytes
        self.data[ids] = values
        if self._sums is not None:
            self.reguard(ids)

    def scatter_add_grad(self, ids: np.ndarray, grads: np.ndarray, lr: float):
        """Baseline path (no-cache / static-cache miss): the memory-bound
        gradient duplication + coalescing + scatter executed on the host
        tier. read-modify-write = 2x row traffic."""
        if self._sums is not None:
            self.verify(ids)
        self.traffic.read += ids.size * self.row_bytes
        self.traffic.written += ids.size * self.row_bytes
        np.subtract.at(self.data, ids, lr * grads)
        if self._sums is not None:
            self.reguard(ids)
