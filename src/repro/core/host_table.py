"""Capacity-tier ("CPU DRAM") embedding table.

The paper keeps the full embedding tables in slow/large CPU memory; gathers
and scatters against it are the bottleneck ScratchPipe removes from the
critical path. Byte counters feed the calibrated bandwidth model used by the
paper-figure benchmarks (this container cannot measure a real two-tier
memory hierarchy).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class HostTraffic:
    """Byte counters for one memory tier / link."""

    read: int = 0
    written: int = 0

    def reset(self):
        self.read = 0
        self.written = 0

    @property
    def total(self) -> int:
        return self.read + self.written


class HostEmbeddingTable:
    """rows x dim fp32 table resident in host memory (numpy).

    For multi-table models (DLRM) the tables are flattened into one global
    row space (global_id = table * rows_per_table + id) — this matches the
    paper's per-table cache managers (ranges never interleave) while keeping
    one vectorized controller.
    """

    def __init__(
        self, rows: int, dim: int, *, seed: int = 0, dtype=np.float32, data=None
    ):
        if data is not None:
            assert data.shape == (rows, dim)
            self.data = data
        else:
            rng = np.random.default_rng(seed)
            scale = 1.0 / np.sqrt(dim)
            self.data = (rng.standard_normal((rows, dim)) * scale).astype(dtype)
        self.traffic = HostTraffic()

    @property
    def rows(self) -> int:
        return self.data.shape[0]

    @property
    def dim(self) -> int:
        return self.data.shape[1]

    @property
    def row_bytes(self) -> int:
        return self.data.shape[1] * self.data.dtype.itemsize

    def gather(self, ids: np.ndarray) -> np.ndarray:
        """[Collect]: read missed rows from the capacity tier."""
        self.traffic.read += ids.size * self.row_bytes
        return self.data[ids]

    def scatter(self, ids: np.ndarray, values: np.ndarray) -> None:
        """[Insert]: write evicted (dirty, trained) rows back."""
        self.traffic.written += ids.size * self.row_bytes
        self.data[ids] = values

    def scatter_add_grad(self, ids: np.ndarray, grads: np.ndarray, lr: float):
        """Baseline path (no-cache / static-cache miss): the memory-bound
        gradient duplication + coalescing + scatter executed on the host
        tier. read-modify-write = 2x row traffic."""
        self.traffic.read += ids.size * self.row_bytes
        self.traffic.written += ids.size * self.row_bytes
        np.subtract.at(self.data, ids, lr * grads)
