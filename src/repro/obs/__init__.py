"""Unified telemetry layer (zero-dependency, strictly opt-in).

Two primitives, one install point:

  * :class:`~repro.obs.metrics.MetricsRegistry` — counters / gauges /
    histograms with a structured JSONL snapshot export
    (``schema obs_metrics/v1``).
  * :class:`~repro.obs.tracing.Tracer` — span-based stage tracing across
    every thread that does pipeline work (main, overlapped host worker,
    d2h worker, serving front-end, replay prefetcher), exported as Chrome
    trace-event JSON loadable in Perfetto / ``chrome://tracing``.

The OFF path is the default everywhere: runtimes take ``tracer=None,
metrics=None`` and fall back to the process-global install below, which is
also ``None`` unless a launcher opted in (``--metrics-out`` /
``--trace-out``). With both unset the hot loop touches a shared null span
singleton and a couple of ``is None`` branches — no dispatches, no
per-cycle allocations (measured in ``benchmarks/overhead.py``), and
metrics-on never perturbs any bit-parity suite (observation reads, never
writes, pipeline state).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracing import NULL_SPAN, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_SPAN",
    "Tracer",
    "install",
    "get_tracer",
    "get_metrics",
    "resolve",
]

# Process-global opt-in point. Threaded components that are not built
# through a runtime constructor (the trace-replay prefetcher, the serving
# front-end) pick their tracer up from here, so one install() call at the
# launcher covers every thread in the process.
_tracer: Optional[Tracer] = None
_metrics: Optional[MetricsRegistry] = None


def install(
    tracer: Optional[Tracer] = None, metrics: Optional[MetricsRegistry] = None
) -> None:
    """Set (or clear, with Nones) the process-global tracer/metrics pair."""
    global _tracer, _metrics
    _tracer = tracer
    _metrics = metrics


def get_tracer() -> Optional[Tracer]:
    return _tracer


def get_metrics() -> Optional[MetricsRegistry]:
    return _metrics


def resolve(
    tracer: Optional[Tracer], metrics: Optional[MetricsRegistry]
) -> Tuple[Optional[Tracer], Optional[MetricsRegistry]]:
    """Constructor-side resolution: an explicit argument wins; ``None``
    falls back to the global install (still ``None`` when nothing opted
    in). Resolution happens ONCE at construction — never per cycle."""
    return (
        tracer if tracer is not None else _tracer,
        metrics if metrics is not None else _metrics,
    )
