"""Span-based tracer with thread-correct wall-clock attribution.

The paper's claims are per-stage overlap claims, so the tracer must answer
"which *thread* spent how long in which *stage*" — exactly what
``StepStats.stage_times`` (main-thread seconds only) cannot. Spans are
recorded on whichever thread opens them: the overlapped executor's host
worker, the d2h worker, the serving front-end, and the replay prefetcher
each get their own event buffer, so a pool-submitted gather shows up on
``scratchpipe-host``, not on the main thread that enqueued it.

Cost model:

  * OFF: runtimes hold :data:`NULL_SPAN`, whose ``__enter__``/``__exit__``
    are empty — no allocation, no clock read.
  * ON: a span is one buffer-registration check, two
    ``perf_counter_ns`` reads, and two tuple appends to a thread-local
    list. No locks on the hot path (the registry lock is taken once per
    thread at first use); buffers are merged only at export.

Export is Chrome trace-event JSON (``B``/``E`` duration events + ``M``
thread-name metadata), loadable in Perfetto / ``chrome://tracing``.
Per-thread timestamps are monotone by construction (each thread appends to
its own buffer in clock order); dangling ``B`` events from threads still
mid-span at export time are balanced with synthesized ``E`` events.

Optional ``jax_annotations=True`` additionally wraps each span in
``jax.profiler.TraceAnnotation`` so stage names line up with device
activity in a jax-profiler capture; it is off by default because it adds
a dispatch per span.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class _NullSpan:
    """Shared do-nothing span: the metrics-off hot path."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


NULL_SPAN = _NullSpan()


class _Span:
    """Context manager that stamps B/E events into its thread's buffer."""

    __slots__ = ("_tracer", "_name", "_cat", "_buf", "_jax_ctx")

    def __init__(self, tracer: "Tracer", name: str, cat: str):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._buf: Optional[list] = None
        self._jax_ctx = None

    def __enter__(self) -> "_Span":
        t = self._tracer
        self._buf = buf = t._thread_buffer()
        buf.append((self._name, self._cat, "B", t._now_us()))
        if t._annotate is not None:
            self._jax_ctx = t._annotate(self._name)
            self._jax_ctx.__enter__()
        return self

    def __exit__(self, *exc) -> None:
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
            self._jax_ctx = None
        self._buf.append((self._name, self._cat, "E", self._tracer._now_us()))
        return None


class Tracer:
    def __init__(self, jax_annotations: bool = False):
        self._t0 = time.perf_counter_ns()
        self._lock = threading.Lock()
        # seq tid -> (thread_name, event buffer). Sequential tids (not
        # thread idents, which the OS reuses) keep two short-lived threads
        # from sharing a lane in the exported trace.
        self._threads: Dict[int, Tuple[str, List[tuple]]] = {}
        self._local = threading.local()
        self._next_tid = 0
        self._annotate: Optional[Callable[[str], Any]] = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation

                self._annotate = TraceAnnotation
            except Exception:
                self._annotate = None

    def _now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _thread_buffer(self) -> List[tuple]:
        buf = getattr(self._local, "buf", None)
        if buf is None:
            buf = []
            with self._lock:
                tid = self._next_tid
                self._next_tid += 1
                self._threads[tid] = (threading.current_thread().name, buf)
            self._local.buf = buf
        return buf

    def span(self, name: str, cat: str = "stage") -> _Span:
        return _Span(self, name, cat)

    def instant(self, name: str, cat: str = "stage") -> None:
        """Zero-duration marker on the current thread."""
        self._thread_buffer().append((name, cat, "I", self._now_us()))

    def wrap(self, name: str, fn: Callable, cat: str = "stage") -> Callable:
        """Wrap ``fn`` so it runs under a span *on the thread that executes
        it* — the hook for pool-submitted work (host gather, d2h copies,
        planner materialize): the span lands on the worker's lane, not on
        the main thread that called ``submit``."""

        def _traced(*args, **kwargs):
            with self.span(name, cat):
                return fn(*args, **kwargs)

        return _traced

    # ---------------------------------------------------------------- export

    def _snapshot_threads(self) -> List[Tuple[int, str, List[tuple]]]:
        with self._lock:
            items = sorted(self._threads.items())
        # Copy each buffer: writer threads may still be appending. A list
        # snapshot via slice is atomic enough (append-only buffers).
        return [(tid, name, list(buf)) for tid, (name, buf) in items]

    def events(self) -> List[dict]:
        """Chrome trace-event dicts, dangling B events balanced."""
        pid = 1
        out: List[dict] = []
        for tid, tname, buf in self._snapshot_threads():
            out.append(
                {
                    "ph": "M",
                    "name": "thread_name",
                    "pid": pid,
                    "tid": tid,
                    "args": {"name": tname},
                }
            )
            open_stack: List[tuple] = []
            last_ts = 0.0
            for name, cat, ph, ts in buf:
                last_ts = ts
                if ph == "B":
                    open_stack.append((name, cat))
                elif ph == "E":
                    if open_stack:
                        open_stack.pop()
                ev = {"ph": ph, "pid": pid, "tid": tid, "ts": ts}
                if ph != "E":
                    ev["name"] = name
                    ev["cat"] = cat
                if ph == "I":
                    ev["s"] = "t"
                out.append(ev)
            # Balance spans still open on this thread at export time.
            while open_stack:
                open_stack.pop()
                out.append({"ph": "E", "pid": pid, "tid": tid, "ts": last_ts})
        return out

    def export_chrome(self, path: str) -> int:
        """Write Chrome trace-event JSON; returns the event count."""
        events = self.events()
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return len(events)

    def totals(self) -> Dict[Tuple[str, str], float]:
        """Aggregate span seconds keyed by (thread_name, span_name) —
        thread-correct per-stage wall time, the replacement for the
        deprecated main-thread-only ``StepStats.stage_times``. Nested spans
        each accrue their own full duration."""
        out: Dict[Tuple[str, str], float] = {}
        for _tid, tname, buf in self._snapshot_threads():
            stack: List[Tuple[str, float]] = []
            for name, _cat, ph, ts in buf:
                if ph == "B":
                    stack.append((name, ts))
                elif ph == "E" and stack:
                    bname, bts = stack.pop()
                    key = (tname, bname)
                    out[key] = out.get(key, 0.0) + (ts - bts) / 1e6
        return out

    def thread_names(self) -> List[str]:
        with self._lock:
            return [name for name, _buf in self._threads.values()]
