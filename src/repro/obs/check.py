"""Schema validators for the telemetry artifacts (+ a tiny CLI).

Used by tests and by the CI ``obs-smoke`` job:

    python -m repro.obs.check --trace t.json --metrics m.jsonl --min-threads 3

Exit status 0 iff every named artifact validates.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Tuple

from repro.obs.metrics import SCHEMA as METRICS_SCHEMA

_KINDS = {"counter", "gauge", "histogram"}


def validate_chrome_trace(path: str, min_threads: int = 1) -> List[str]:
    """Return a list of problems (empty == valid).

    Checks: well-formed JSON with a ``traceEvents`` list; every event has
    ph/pid/tid/ts fields as appropriate; per-tid timestamps are monotone
    non-decreasing; B/E events are balanced per tid; at least
    ``min_threads`` distinct tids carry at least one B event.
    """
    problems: List[str] = []
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception as e:
        return [f"unreadable JSON: {type(e).__name__}: {e}"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing traceEvents list"]
    last_ts = {}
    depth = {}
    threads_with_spans = set()
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("B", "E", "I", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        tid = ev.get("tid")
        if tid is None:
            problems.append(f"event {i}: missing tid")
            continue
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            problems.append(f"event {i}: missing/invalid ts")
            continue
        if ts < last_ts.get(tid, 0.0):
            problems.append(
                f"event {i}: tid {tid} ts {ts} < previous {last_ts[tid]}"
            )
        last_ts[tid] = ts
        if ph == "B":
            if "name" not in ev:
                problems.append(f"event {i}: B without name")
            depth[tid] = depth.get(tid, 0) + 1
            threads_with_spans.add(tid)
        elif ph == "E":
            depth[tid] = depth.get(tid, 0) - 1
            if depth[tid] < 0:
                problems.append(f"event {i}: tid {tid} E without matching B")
    for tid, d in depth.items():
        if d > 0:
            problems.append(f"tid {tid}: {d} unbalanced B event(s)")
    if len(threads_with_spans) < min_threads:
        problems.append(
            f"only {len(threads_with_spans)} thread(s) carry spans, "
            f"need >= {min_threads}"
        )
    return problems


def validate_metrics_jsonl(path: str) -> List[str]:
    """Return a list of problems (empty == valid) for an obs_metrics/v1
    JSONL snapshot: meta header first, then one record per instrument with
    kind/name/labels and kind-appropriate value fields."""
    problems: List[str] = []
    try:
        with open(path) as f:
            lines = [ln for ln in f.read().splitlines() if ln.strip()]
    except Exception as e:
        return [f"unreadable file: {type(e).__name__}: {e}"]
    if not lines:
        return ["empty file"]
    records = []
    for i, ln in enumerate(lines):
        try:
            records.append(json.loads(ln))
        except Exception as e:
            problems.append(f"line {i}: invalid JSON: {e}")
    if problems:
        return problems
    head = records[0]
    if head.get("schema") != METRICS_SCHEMA or head.get("kind") != "meta":
        problems.append(
            f"line 0: expected meta header with schema {METRICS_SCHEMA!r}"
        )
    elif head.get("num_metrics") != len(records) - 1:
        problems.append(
            f"header num_metrics {head.get('num_metrics')} != "
            f"{len(records) - 1} records"
        )
    for i, r in enumerate(records[1:], start=1):
        kind = r.get("kind")
        if kind not in _KINDS:
            problems.append(f"line {i}: unknown kind {kind!r}")
            continue
        if not isinstance(r.get("name"), str):
            problems.append(f"line {i}: missing name")
        if not isinstance(r.get("labels"), dict):
            problems.append(f"line {i}: missing labels")
        if kind == "counter":
            if not isinstance(r.get("value"), int):
                problems.append(f"line {i}: counter value must be int")
        elif kind == "histogram":
            if not isinstance(r.get("count"), int) or not isinstance(
                r.get("buckets"), list
            ):
                problems.append(f"line {i}: histogram needs count + buckets")
        elif kind == "gauge":
            v = r.get("value")
            if v is not None and not isinstance(v, (int, float)):
                problems.append(f"line {i}: gauge value must be numeric/null")
    return problems


def _report(label: str, problems: List[str]) -> bool:
    if problems:
        print(f"FAIL {label}:")
        for p in problems:
            print(f"  - {p}")
        return False
    print(f"OK   {label}")
    return True


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="Validate telemetry artifacts")
    ap.add_argument("--trace", help="Chrome trace-event JSON file")
    ap.add_argument("--metrics", help="obs_metrics/v1 JSONL file")
    ap.add_argument(
        "--min-threads",
        type=int,
        default=1,
        help="minimum distinct threads that must carry spans in --trace",
    )
    args = ap.parse_args(argv)
    if not args.trace and not args.metrics:
        ap.error("nothing to check: pass --trace and/or --metrics")
    ok = True
    if args.trace:
        ok &= _report(
            f"trace {args.trace}",
            validate_chrome_trace(args.trace, min_threads=args.min_threads),
        )
    if args.metrics:
        ok &= _report(
            f"metrics {args.metrics}", validate_metrics_jsonl(args.metrics)
        )
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
