"""Metrics registry: counters, gauges, histograms + JSONL snapshot export.

Design constraints (the telemetry tentpole's contract):

  * **Zero hot-path cost when off.** Instruments are plain objects a
    runtime holds only when a registry was passed in; the off path never
    touches this module after import.
  * **Cheap when on.** ``Counter.inc`` is one lock + one int add (~100 ns);
    ``Histogram.observe`` is a log2 bucket index. Byte counters are NOT
    duplicated here — the runtimes already keep unconditional
    ``HostTraffic`` totals, which a :class:`Gauge` reads lazily through its
    ``fn`` callback at snapshot time, so traffic metrics cost nothing per
    cycle even when metrics are on.
  * **Thread-correct.** Counters/histograms take a lock (the overlapped
    executor's workers and the serving front-end increment from their own
    threads); gauges are read-only probes evaluated at snapshot time.

Snapshot format (``write_jsonl``): one JSON object per line. The first
line is a meta header ``{"schema": "obs_metrics/v1", "kind": "meta", ...}``
carrying caller provenance; every following line is one instrument with
``kind`` / ``name`` / ``labels`` and its values. Validated by
``repro.obs.check.validate_metrics_jsonl``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

SCHEMA = "obs_metrics/v1"

# Histogram buckets: value v lands in bucket floor(log2(v)) + 1 (bucket 0
# holds v < 1). 64 buckets cover the full int64 range — enough for
# microsecond latencies from sub-µs to weeks.
_NUM_BUCKETS = 64


class Counter:
    """Monotone counter (thread-safe)."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: Dict[str, str]):
        self.name = name
        self.labels = labels
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def snapshot(self) -> dict:
        return {
            "kind": "counter",
            "name": self.name,
            "labels": self.labels,
            "value": self._value,
        }


class Gauge:
    """Point-in-time value: either ``set()`` explicitly or backed by a
    zero-argument ``fn`` probe evaluated lazily at snapshot time (the
    mechanism that turns the runtimes' existing unconditional byte counters
    into metrics with no per-cycle cost)."""

    __slots__ = ("name", "labels", "fn", "_value")

    def __init__(
        self,
        name: str,
        labels: Dict[str, str],
        fn: Optional[Callable[[], Any]] = None,
    ):
        self.name = name
        self.labels = labels
        self.fn = fn
        self._value: Any = None

    def set(self, value: Any) -> None:
        self._value = value

    @property
    def value(self) -> Any:
        if self.fn is not None:
            return self.fn()
        return self._value

    def snapshot(self) -> dict:
        try:
            v = self.value
        except Exception as e:  # a probe must never kill the snapshot
            return {
                "kind": "gauge",
                "name": self.name,
                "labels": self.labels,
                "value": None,
                "error": f"{type(e).__name__}: {e}",
            }
        if v is not None:
            v = float(v) if isinstance(v, float) else int(v)
        return {
            "kind": "gauge",
            "name": self.name,
            "labels": self.labels,
            "value": v,
        }


class Histogram:
    """Log2-bucketed histogram with count/sum/min/max and estimated
    percentiles. ``unit`` is descriptive only (the serve-latency histogram
    observes microseconds). Preallocated buckets — ``observe`` allocates
    nothing."""

    __slots__ = ("name", "labels", "unit", "_buckets", "_count", "_sum",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, labels: Dict[str, str], unit: str = "us"):
        self.name = name
        self.labels = labels
        self.unit = unit
        self._buckets = [0] * _NUM_BUCKETS
        self._count = 0
        self._sum = 0.0
        self._min = None
        self._max = None
        self._lock = threading.Lock()

    @staticmethod
    def _bucket_of(v: float) -> int:
        iv = int(v)
        if iv < 1:
            return 0
        return min(_NUM_BUCKETS - 1, iv.bit_length())

    def observe(self, v: float) -> None:
        b = self._bucket_of(v)
        with self._lock:
            self._buckets[b] += 1
            self._count += 1
            self._sum += v
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, p: float) -> Optional[float]:
        """Upper bucket-edge estimate of the p-th percentile (0..100)."""
        if self._count == 0:
            return None
        target = max(1, int(round(self._count * p / 100.0)))
        seen = 0
        for b, n in enumerate(self._buckets):
            seen += n
            if seen >= target:
                return float(1 << b)  # upper edge of bucket b
        return float(self._max)

    def snapshot(self) -> dict:
        return {
            "kind": "histogram",
            "name": self.name,
            "labels": self.labels,
            "unit": self.unit,
            "count": self._count,
            "sum": self._sum,
            "min": self._min,
            "max": self._max,
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "buckets": list(self._buckets),
        }


def _label_key(name: str, labels: Dict[str, str]) -> Tuple:
    return (name,) + tuple(sorted(labels.items()))


class MetricsRegistry:
    """Get-or-create registry keyed on (name, sorted labels). Repeated
    ``counter(...)`` calls with the same identity return the SAME cell, so
    instruments can be created eagerly at construction and incremented
    without lookups on the hot path."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple, Any] = {}

    def _get_or_create(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (cls.__name__,) + _label_key(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, labels, **kw)
                self._metrics[key] = m
            return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get_or_create(Counter, name, labels)

    def gauge(
        self, name: str, fn: Optional[Callable[[], Any]] = None, **labels: str
    ) -> Gauge:
        g = self._get_or_create(Gauge, name, labels)
        if fn is not None:
            g.fn = fn
        return g

    def histogram(self, name: str, unit: str = "us", **labels: str) -> Histogram:
        return self._get_or_create(Histogram, name, labels, unit=unit)

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> List[dict]:
        with self._lock:
            metrics = list(self._metrics.values())
        return [m.snapshot() for m in metrics]

    def write_jsonl(
        self, path: str, provenance: Optional[dict] = None
    ) -> List[dict]:
        """Export one meta header line + one line per instrument. Returns
        the snapshot records (header excluded) for callers that also want
        the values in-process."""
        records = self.snapshot()
        header = {
            "schema": SCHEMA,
            "kind": "meta",
            "created_unix": time.time(),
            "num_metrics": len(records),
            "provenance": provenance or {},
        }
        with open(path, "w") as f:
            f.write(json.dumps(header) + "\n")
            for r in records:
                f.write(json.dumps(r) + "\n")
        return records
