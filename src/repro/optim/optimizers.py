"""Optimizers (no external deps): SGD, AdamW (with fp32 master weights for
bf16 params), row-wise Adagrad (the standard embedding-table optimizer).

API: ``opt.init(params) -> state``; ``opt.step(params, grads, state, lr) ->
(params, state)``. States are plain pytrees (checkpointable / shardable —
ZeRO-1 shards them over the data axes via parallel.sharding.zero1_spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


@dataclasses.dataclass(frozen=True)
class SGD:
    momentum: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def step(self, params, grads, state, lr):
        if self.momentum == 0.0:
            new = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)).astype(p.dtype),
                params,
                grads,
            )
            return new, state
        vel = jax.tree.map(
            lambda v, g: self.momentum * v + g.astype(jnp.float32), state, grads
        )
        new = jax.tree.map(
            lambda p, v: (p.astype(jnp.float32) - lr * v).astype(p.dtype), params, vel
        )
        return new, vel


@dataclasses.dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    master_fp32: bool = True  # keep fp32 master copy when params are low-prec

    def init(self, params):
        st = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }
        if self.master_fp32:
            st["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return st

    def step(self, params, grads, state, lr):
        t = state["t"] + 1
        b1t = 1.0 - self.b1 ** t.astype(jnp.float32)
        b2t = 1.0 - self.b2 ** t.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: self.b1 * m_ + (1 - self.b1) * g.astype(jnp.float32),
            state["m"],
            grads,
        )
        v = jax.tree.map(
            lambda v_, g: self.b2 * v_ + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
            state["v"],
            grads,
        )
        base = state["master"] if self.master_fp32 else params

        def upd(p32, m_, v_):
            mh = m_ / b1t
            vh = v_ / b2t
            step = lr * (mh / (jnp.sqrt(vh) + self.eps) + self.weight_decay * p32)
            return p32.astype(jnp.float32) - step

        new_master = jax.tree.map(upd, base, m, v)
        new_params = jax.tree.map(
            lambda p, nm: nm.astype(p.dtype), params, new_master
        )
        st = {"m": m, "v": v, "t": t}
        if self.master_fp32:
            st["master"] = new_master
        return new_params, st


@dataclasses.dataclass(frozen=True)
class RowWiseAdagrad:
    """One accumulator per embedding ROW (Facebook's DLRM embedding optimizer)
    — 1/D the state of full Adagrad; the natural choice for scratchpad rows."""

    eps: float = 1e-8

    def init_rows(self, num_rows: int):
        return jnp.zeros((num_rows,), jnp.float32)

    def step_rows(self, rows, row_grads, acc, lr):
        """rows (n, D) updated with grads (n, D); acc (n,) gathered slice."""
        g2 = jnp.mean(jnp.square(row_grads.astype(jnp.float32)), axis=-1)
        acc = acc + g2
        scale = lr / (jnp.sqrt(acc) + self.eps)
        new = rows.astype(jnp.float32) - scale[:, None] * row_grads.astype(jnp.float32)
        return new.astype(rows.dtype), acc


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def warmup_cosine(step, *, base_lr: float, warmup: int, total: int, min_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * progress)))
    return jnp.where(step < warmup, warm, cos)
