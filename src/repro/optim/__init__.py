from repro.optim.optimizers import (  # noqa: F401
    AdamW,
    RowWiseAdagrad,
    SGD,
    clip_by_global_norm,
    global_norm,
    warmup_cosine,
)
