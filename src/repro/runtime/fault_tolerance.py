"""Fault tolerance: supervised training loop with checkpoint/restart,
NaN-step quarantine, and preemption-aware save.

At 1000+-node scale the failure model is: a worker dies (XLA collective
error / host crash) -> the coordinator restarts the job -> the supervisor
restores the latest checkpoint, fast-forwards the (deterministic) data
stream, and resumes; the ScratchPipe planner state is host state and is
checkpointed alongside. This container exercises the same control flow with
injected failures (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class PreemptionHandler:
    """SIGTERM -> checkpoint at the next step boundary (SLURM/Borg style)."""

    def __init__(self, install: bool = False):
        self.requested = False
        if install:
            signal.signal(signal.SIGTERM, self._on_signal)

    def _on_signal(self, *_):
        self.requested = True


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    nan_steps_skipped: int = 0
    last_step: int = 0


class TrainSupervisor:
    """Runs ``step_fn(state, batch) -> (state, metrics)`` over a stream with
    periodic checkpoints and automatic restore-on-failure.

    * ``stream_factory(skip)`` rebuilds the batch iterator positioned after
      ``skip`` consumed batches (deterministic replay).
    * transient exceptions and non-finite losses trigger restore+resume
      (up to ``max_restarts``).
    """

    def __init__(
        self,
        ckpt: CheckpointManager,
        step_fn: Callable[[Any, Any], tuple],
        stream_factory: Callable[[int], Iterator],
        *,
        ckpt_every: int = 50,
        max_restarts: int = 5,
        nan_policy: str = "restore",  # "restore" | "skip" | "raise"
        preemption: Optional[PreemptionHandler] = None,
    ):
        self.ckpt = ckpt
        self.step_fn = step_fn
        self.stream_factory = stream_factory
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.nan_policy = nan_policy
        self.preemption = preemption or PreemptionHandler()

    def run(self, state, total_steps: int, *, shardings=None) -> tuple:
        report = SupervisorReport()
        step = 0
        # resume if a checkpoint exists
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(state, shardings=shardings)
        stream = self.stream_factory(step)
        restarts = 0
        while step < total_steps:
            try:
                batch = next(stream)
            except StopIteration:
                break
            try:
                new_state, metrics = self.step_fn(state, batch)
                loss = metrics.get("loss")
                if loss is not None and not np.isfinite(float(loss)):
                    report.nan_steps_skipped += 1
                    if self.nan_policy == "raise":
                        raise FloatingPointError(f"non-finite loss at step {step}")
                    if self.nan_policy == "restore":
                        raise _NonFinite(step)
                    # "skip": drop the update, keep going
                    new_state = state
                state = new_state
                step += 1
                report.steps_run += 1
                report.last_step = step
                if step % self.ckpt_every == 0 or self.preemption.requested:
                    self.ckpt.save(step, state)
                    if self.preemption.requested:
                        self.ckpt.wait()
                        break
            except (_NonFinite, RuntimeError, FloatingPointError) as e:
                if isinstance(e, FloatingPointError) and self.nan_policy == "raise":
                    raise
                restarts += 1
                report.restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}"
                    ) from e
                if self.ckpt.latest_step() is None:
                    # no checkpoint yet: restart from scratch
                    step = 0
                    stream = self.stream_factory(0)
                    continue
                state, step = self.ckpt.restore(state, shardings=shardings)
                stream = self.stream_factory(step)
        self.ckpt.wait()
        return state, report


class _NonFinite(Exception):
    pass


class FailureInjector:
    """Deterministically raise at given step numbers (tests/benchmarks)."""

    def __init__(self, fail_at):
        self.fail_at = set(fail_at)
        self.calls = 0

    def maybe_fail(self):
        self.calls += 1
        if self.calls in self.fail_at:
            raise RuntimeError(f"injected node failure at call {self.calls}")
