"""Fault tolerance: supervised training loop with checkpoint/restart,
NaN-step quarantine, and preemption-aware save.

At 1000+-node scale the failure model is: a worker dies (XLA collective
error / host crash) -> the coordinator restarts the job -> the supervisor
restores the latest checkpoint, fast-forwards the (deterministic) data
stream, and resumes; the ScratchPipe planner state is host state and is
checkpointed alongside. This container exercises the same control flow with
injected failures (tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.host_table import RowCorruptionError
from repro.runtime.supervision import TransientOpError


class PreemptionHandler:
    """SIGTERM -> checkpoint at the next step boundary (SLURM/Borg style)."""

    def __init__(self, install: bool = False):
        self.requested = False
        if install:
            signal.signal(signal.SIGTERM, self._on_signal)

    def _on_signal(self, *_):
        self.requested = True


@dataclasses.dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    nan_steps_skipped: int = 0
    last_step: int = 0
    checkpoints: int = 0
    # wall-clock of each restore (rebuild + load), feeding the MTTR bench
    restore_ms: list = dataclasses.field(default_factory=list)


class TrainSupervisor:
    """Runs ``step_fn(state, batch) -> (state, metrics)`` over a stream with
    periodic checkpoints and automatic restore-on-failure.

    * ``stream_factory(skip)`` rebuilds the batch iterator positioned after
      ``skip`` consumed batches (deterministic replay).
    * transient exceptions and non-finite losses trigger restore+resume
      (up to ``max_restarts``).
    """

    def __init__(
        self,
        ckpt: CheckpointManager,
        step_fn: Callable[[Any, Any], tuple],
        stream_factory: Callable[[int], Iterator],
        *,
        ckpt_every: int = 50,
        max_restarts: int = 5,
        nan_policy: str = "restore",  # "restore" | "skip" | "raise"
        preemption: Optional[PreemptionHandler] = None,
    ):
        self.ckpt = ckpt
        self.step_fn = step_fn
        self.stream_factory = stream_factory
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.nan_policy = nan_policy
        self.preemption = preemption or PreemptionHandler()

    def run(self, state, total_steps: int, *, shardings=None) -> tuple:
        report = SupervisorReport()
        step = 0
        # resume if a checkpoint exists
        if self.ckpt.latest_step() is not None:
            state, step = self.ckpt.restore(state, shardings=shardings)
        stream = self.stream_factory(step)
        restarts = 0
        while step < total_steps:
            try:
                batch = next(stream)
            except StopIteration:
                break
            try:
                new_state, metrics = self.step_fn(state, batch)
                loss = metrics.get("loss")
                if loss is not None and not np.isfinite(float(loss)):
                    report.nan_steps_skipped += 1
                    if self.nan_policy == "raise":
                        raise FloatingPointError(f"non-finite loss at step {step}")
                    if self.nan_policy == "restore":
                        raise _NonFinite(step)
                    # "skip": drop the update, keep going
                    new_state = state
                state = new_state
                step += 1
                report.steps_run += 1
                report.last_step = step
                if step % self.ckpt_every == 0 or self.preemption.requested:
                    self.ckpt.save(step, state)
                    if self.preemption.requested:
                        self.ckpt.wait()
                        break
            except (_NonFinite, RuntimeError, FloatingPointError) as e:
                if isinstance(e, FloatingPointError) and self.nan_policy == "raise":
                    raise
                restarts += 1
                report.restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}"
                    ) from e
                if self.ckpt.latest_step() is None:
                    # no checkpoint yet: restart from scratch
                    step = 0
                    stream = self.stream_factory(0)
                    continue
                state, step = self.ckpt.restore(state, shardings=shardings)
                stream = self.stream_factory(step)
        self.ckpt.wait()
        return state, report


class _NonFinite(Exception):
    pass


class EmbeddingTrainSupervisor:
    """Checkpoint/restart supervision for embedding-cache RUNTIMES (the
    pipelined designs of ``repro.core``), as opposed to the plain
    ``step_fn`` loop of :class:`TrainSupervisor`.

    The extra difficulty over a stateless step loop is the hold window: a
    pipelined runtime has up to ``window`` mini-batches in flight, so "the
    checkpoint at batch N" must capture planner state, scratchpad, host
    table AND the in-flight entries — which ``state_arrays()`` now does at
    any cycle. The supervisor's restart contract is therefore exact: a run
    that is killed and restored produces bit-identical losses and cache
    decisions to one that never failed (tests/test_recovery.py).

    * ``runtime_factory() -> (runtime, trainer_or_None)`` rebuilds the full
      stack from scratch — host table, trainer, runtime, and (in chaos
      runs) the fault injector — modeling a process restart. ``trainer``
      (e.g. ``DLRMTrainer``) contributes its dense params (``.mlps``) and
      stochastic-rounding step counter to the checkpoint.
    * ``stream_factory(skip)`` re-creates the deterministic batch stream
      positioned after ``skip`` admitted batches; streams exposing
      ``peek_ids`` (TraceReplayStream, LookaheadStream) also drive the
      planner's look-ahead.
    * Recoverable faults — worker death/timeouts (``TransientOpError``),
      host-row corruption (``RowCorruptionError``), non-finite losses under
      ``nan_policy="restore"``, and runtime errors generally — trigger
      rebuild + restore + fast-forward, bounded by ``max_restarts``.
    * ``verify_every=k`` audits the host table's row checksums every k
      cycles (requires ``enable_guard()``; the chaos harness arms it).

    ``nan_policy="skip"`` only counts non-finite losses: with a pipelined
    runtime the embedding update has already landed by the time the loss is
    observable, so a true skip is unsound — use "restore" to excise it.
    """

    def __init__(
        self,
        ckpt: CheckpointManager,
        runtime_factory: Callable[[], tuple],
        stream_factory: Callable[[int], Iterator],
        *,
        ckpt_every: int = 10,
        max_restarts: int = 5,
        nan_policy: str = "restore",  # "restore" | "skip" | "raise"
        verify_every: int = 0,
        blocking_saves: bool = False,
        preemption: Optional[PreemptionHandler] = None,
    ):
        self.ckpt = ckpt
        self.runtime_factory = runtime_factory
        self.stream_factory = stream_factory
        self.ckpt_every = int(ckpt_every)
        self.max_restarts = int(max_restarts)
        if nan_policy not in ("restore", "skip", "raise"):
            raise ValueError(f"unknown nan_policy {nan_policy!r}")
        self.nan_policy = nan_policy
        self.verify_every = int(verify_every)
        self.blocking_saves = blocking_saves
        self.preemption = preemption or PreemptionHandler()
        self.runtime = None  # the live runtime after run() returns
        self.trainer = None
        self._last_saved = -1

    # -- runtime introspection (ScratchPipe / Sharded / serving) ----------- #
    @staticmethod
    def _in_flight(rt) -> int:
        w = getattr(rt, "_window", None)
        if w is not None:
            return len(w)
        pipes = getattr(rt, "pipes", None)
        if pipes:
            return len(pipes[-1]._window)
        return 0

    @staticmethod
    def _hosts(rt) -> list:
        pipes = getattr(rt, "pipes", None)
        if pipes:
            return [p.host for p in pipes]
        return [rt.host]

    @staticmethod
    def _loss_of(st) -> Optional[float]:
        aux = st.aux
        if isinstance(aux, dict):
            aux = aux.get("loss")
        if aux is None:
            return None
        try:
            return float(np.asarray(aux))
        except (TypeError, ValueError):
            return None

    # -- checkpoint plumbing ----------------------------------------------- #
    def _save(self, admitted: int, trained: int, rt, trainer, report) -> None:
        state = {"mlps": trainer.mlps} if trainer is not None else {}
        extra = {"admitted": admitted, "trained": trained}
        if trainer is not None and hasattr(trainer, "_step"):
            extra["trainer_step"] = int(trainer._step)
        self.ckpt.save(
            admitted,
            state,
            host_arrays=rt.state_arrays(),
            extra=extra,
            blocking=self.blocking_saves,
        )
        report.checkpoints += 1
        self._last_saved = admitted

    def _restore(self, rt, trainer) -> tuple:
        """Load the latest checkpoint into a freshly built runtime/trainer.
        Returns (admitted, trained) — the stream position and the number of
        completed training steps at the snapshot."""
        man = self.ckpt.manifest()
        arrays = {name: self.ckpt.restore_host(name) for name in man["host"]}
        rt.load_state_arrays(arrays)
        if trainer is not None:
            state, _ = self.ckpt.restore({"mlps": trainer.mlps})
            trainer.mlps = state["mlps"]
            if "trainer_step" in man.get("extra", {}):
                trainer._step = int(man["extra"]["trainer_step"])
        extra = man.get("extra", {})
        admitted = int(extra.get("admitted", man["step"]))
        self._last_saved = admitted
        return admitted, int(extra.get("trained", 0))

    # -- the supervised loop ------------------------------------------------ #
    def run(self, total_steps: int) -> tuple:
        report = SupervisorReport()
        rt, trainer = self.runtime_factory()
        stats: list = []
        admitted = 0
        if self.ckpt.latest_step() is not None:
            t0 = time.perf_counter()
            admitted, trained = self._restore(rt, trainer)
            del stats[trained:]
            report.restore_ms.append((time.perf_counter() - t0) * 1e3)
        stream = self.stream_factory(admitted)
        it = iter(stream)
        peek = getattr(stream, "peek_ids", None)
        restarts = 0
        cycles = 0
        while True:
            try:
                st = None
                exhausted = getattr(stream, "exhausted", False)
                if admitted < total_steps and not exhausted:
                    try:
                        ids, batch = next(it)
                    except StopIteration:
                        if self._in_flight(rt) == 0:
                            break
                        st = rt.drain_one_cycle()
                    else:
                        st = rt.run_one_cycle(ids, batch, peek)
                        admitted += 1
                else:
                    if self._in_flight(rt) == 0:
                        break
                    st = rt.drain_one_cycle()
                cycles += 1
                if st is not None:
                    stats.append(st)
                    report.steps_run += 1
                    report.last_step = int(st.step)
                    loss = self._loss_of(st)
                    if loss is not None and not np.isfinite(loss):
                        report.nan_steps_skipped += 1
                        if self.nan_policy == "raise":
                            raise FloatingPointError(
                                f"non-finite loss at step {st.step}"
                            )
                        if self.nan_policy == "restore":
                            raise _NonFinite(st.step)
                        # "skip": the update already landed; count only
                if self.verify_every and cycles % self.verify_every == 0:
                    for h in self._hosts(rt):
                        h.verify()
                due = (
                    admitted > 0
                    and admitted % self.ckpt_every == 0
                    and admitted != self._last_saved
                )
                if due or (
                    self.preemption.requested and admitted != self._last_saved
                ):
                    self._save(admitted, len(stats), rt, trainer, report)
                    if self.preemption.requested:
                        self.ckpt.wait()
                        break
            except (
                _NonFinite,
                TransientOpError,
                RowCorruptionError,
                FloatingPointError,
                RuntimeError,
            ) as e:
                if (
                    isinstance(e, FloatingPointError)
                    and self.nan_policy == "raise"
                ):
                    raise
                restarts += 1
                report.restarts += 1
                if restarts > self.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.max_restarts}"
                    ) from e
                t0 = time.perf_counter()
                try:  # release the dead runtime's worker threads
                    rt.close()
                except Exception:
                    pass
                rt, trainer = self.runtime_factory()
                if self.ckpt.latest_step() is not None:
                    admitted, trained = self._restore(rt, trainer)
                    del stats[trained:]
                else:
                    admitted = 0
                    stats.clear()
                report.restore_ms.append((time.perf_counter() - t0) * 1e3)
                stream = self.stream_factory(admitted)
                it = iter(stream)
                peek = getattr(stream, "peek_ids", None)
        self.ckpt.wait()
        self.runtime, self.trainer = rt, trainer
        return stats, report


class FailureInjector:
    """Deterministically raise at given step numbers (tests/benchmarks)."""

    def __init__(self, fail_at):
        self.fail_at = set(fail_at)
        self.calls = 0

    def maybe_fail(self):
        self.calls += 1
        if self.calls in self.fail_at:
            raise RuntimeError(f"injected node failure at call {self.calls}")
