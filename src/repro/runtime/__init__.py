from repro.runtime.fault_tolerance import (  # noqa: F401
    FailureInjector,
    PreemptionHandler,
    TrainSupervisor,
)
from repro.runtime.straggler import (  # noqa: F401
    StepTimeMonitor,
    StragglerPolicy,
    plan_rebalance,
)
