from repro.runtime.fault_tolerance import (  # noqa: F401
    EmbeddingTrainSupervisor,
    FailureInjector,
    PreemptionHandler,
    SupervisorReport,
    TrainSupervisor,
)
from repro.runtime.straggler import (  # noqa: F401
    StepTimeMonitor,
    StragglerPolicy,
    plan_rebalance,
)
from repro.runtime.supervision import (  # noqa: F401
    OpSupervisor,
    OpTimeoutError,
    SupervisePolicy,
    SupervisedOp,
    TransientOpError,
)
