"""Supervised execution primitives for the overlapped executor.

The overlapped ScratchPipe executor runs host work (gathers, write-backs)
on one ordered worker thread and d2h materializations on another. Today a
thread death or hang in either pool stalls the pipeline or silently drops
a write-back. This module adds the watchdog layer:

* :class:`SupervisedOp` — a submitted unit of work (fn + args + future).
  The function and its arguments are retained so the op can be REcomputed
  inline on the submitting thread if the worker dies or times out. Every
  pipeline host op is a pure read (host gather) or an idempotent write
  (host scatter of evicted rows / d2h device read), so an inline replay
  produces byte-identical results and preserves the sync-order
  interleaving on the host table — recovery never breaks bit-parity.
* :class:`SupervisePolicy` — per-op timeout, bounded retries with
  backoff, and the degradation threshold.
* :class:`OpSupervisor` — counts faults, performs the bounded inline
  retries, and decides when to give up on the pools entirely
  (``should_degrade`` → the pipe falls back to ``executor="sync"``).

Fault taxonomy: anything raised by a worker (or a timeout waiting on one)
is wrapped in :class:`TransientOpError` subclasses so supervisors up the
stack (``EmbeddingTrainSupervisor``) can distinguish recoverable pipeline
faults from programming errors.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import CancelledError, Future
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Any, Callable, Optional, Tuple


class TransientOpError(RuntimeError):
    """A pipeline op failed in a way that is expected to be recoverable
    (worker death, timeout, injected fault)."""


class OpTimeoutError(TransientOpError):
    """An op exceeded the supervised per-op timeout."""


@dataclasses.dataclass
class SupervisePolicy:
    """Watchdog knobs for the overlapped executor.

    op_timeout:    seconds to wait on any single worker/d2h op before
                   treating it as stalled.
    max_retries:   inline recompute attempts per op after the first
                   failure (bounded retry).
    backoff:       sleep before retry k is ``backoff * 2**k`` seconds.
    degrade_after: after this many recovery incidents the pools are shut
                   down and the pipe degrades to the sync executor for the
                   rest of the run (graceful degradation — correctness
                   over speed).
    """

    op_timeout: float = 30.0
    max_retries: int = 2
    backoff: float = 0.05
    degrade_after: int = 3


_MISSING = object()


class SupervisedOp:
    """One submitted host/d2h op: future + enough to recompute it inline."""

    __slots__ = ("fn", "args", "future", "_value", "label")

    def __init__(self, fn: Callable, args: Tuple, label: str = ""):
        self.fn = fn
        self.args = args
        self.future: Optional[Future] = None
        self._value: Any = _MISSING
        self.label = label or getattr(fn, "__name__", "op")

    @classmethod
    def completed(cls, fn: Callable, args: Tuple, value: Any) -> "SupervisedOp":
        op = cls(fn, args)
        op._value = value
        return op

    @property
    def settled(self) -> bool:
        return self._value is not _MISSING

    @property
    def value(self) -> Any:
        assert self._value is not _MISSING, f"op {self.label} not settled"
        return self._value

    def probe_done(self) -> bool:
        """True if the op has a cached value or its future has completed
        (successfully or not) — never blocks."""
        return self.settled or (self.future is not None and self.future.done())

    def result_now(self) -> Any:
        """Unsupervised semantics: plain blocking wait, raise on failure."""
        if not self.settled:
            self._value = self.future.result()
        return self._value

    def wait(self, timeout: Optional[float]) -> Any:
        """Wait up to ``timeout``; cache + return the value. Raises
        :class:`OpTimeoutError` on timeout, :class:`TransientOpError`
        wrapping whatever the worker raised on failure."""
        if self.settled:
            return self._value
        try:
            self._value = self.future.result(timeout=timeout)
        except FutureTimeoutError as e:
            raise OpTimeoutError(
                f"op {self.label} exceeded {timeout}s"
            ) from e
        except TransientOpError:
            raise
        except (CancelledError, BaseException) as e:
            raise TransientOpError(f"op {self.label} failed: {e!r}") from e
        return self._value

    def settle(self, value: Any) -> None:
        self._value = value


class OpSupervisor:
    """Fault accounting + bounded inline recovery for supervised ops."""

    def __init__(self, policy: SupervisePolicy, metrics=None, tracer=None):
        self.policy = policy
        self.incidents = 0
        self.retries = 0
        self.timeouts = 0
        self.failures = 0
        self.degraded = False
        self._lock = threading.Lock()
        self._c_fail = self._c_timeout = self._c_retry = None
        self._c_recover = self._c_degraded = None
        self.tracer = tracer
        if metrics is not None:
            # ops that raised/died, ops past op_timeout, inline recompute
            # attempts, ops recovered inline, degradations to sync
            self._c_fail = metrics.counter("ft.op_failures")
            self._c_timeout = metrics.counter("ft.op_timeouts")
            self._c_retry = metrics.counter("ft.retries")
            self._c_recover = metrics.counter("ft.inline_recoveries")
            self._c_degraded = metrics.counter("ft.degraded")

    def note_failure(self, err: BaseException) -> None:
        with self._lock:
            self.failures += 1
            if isinstance(err, OpTimeoutError):
                self.timeouts += 1
        if self._c_fail is not None:
            self._c_fail.inc()
        if isinstance(err, OpTimeoutError) and self._c_timeout is not None:
            self._c_timeout.inc()

    def note_incident(self) -> bool:
        """Record one recovery incident; True if the pipe should degrade."""
        with self._lock:
            self.incidents += 1
            hit = self.incidents >= self.policy.degrade_after
        return hit

    def note_degraded(self) -> None:
        self.degraded = True
        if self._c_degraded is not None:
            self._c_degraded.inc()

    def run_inline(self, op: SupervisedOp) -> Any:
        """Recompute ``op`` on the calling thread with bounded retries +
        exponential backoff. Settles the op with the recomputed value."""
        last: Optional[BaseException] = None
        for attempt in range(self.policy.max_retries + 1):
            if attempt:
                time.sleep(self.policy.backoff * (2 ** (attempt - 1)))
            if self._c_retry is not None:
                self._c_retry.inc()
            with self._lock:
                self.retries += 1
            try:
                value = op.fn(*op.args)
            except Exception as e:  # noqa: BLE001 — bounded, then re-raised
                last = e
                continue
            op.settle(value)
            if self._c_recover is not None:
                self._c_recover.inc()
            return value
        raise TransientOpError(
            f"op {op.label} failed after {self.policy.max_retries + 1} "
            f"inline attempts"
        ) from last

    def value_or_inline(self, op: SupervisedOp) -> Any:
        """Wait for ``op`` under the policy timeout; on timeout/failure fall
        straight to the bounded inline recompute. Safe from ANY thread (no
        queue walking) — used by the host worker to resolve d2h ops."""
        try:
            return op.wait(self.policy.op_timeout)
        except TransientOpError as e:
            self.note_failure(e)
            return self.run_inline(op)
