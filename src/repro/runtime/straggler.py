"""Straggler detection & mitigation.

In a synchronous SPMD job one slow host stalls every collective, so the
mitigations are (a) detect persistent stragglers from per-host step times,
(b) rebalance input shards away from them (data-parallel work is the only
freely movable quantity), and (c) at extreme scale, drop-and-replace the
host (handled by the elastic restart path in runtime.elastic).

The detection/rebalancing logic is pure and unit-tested; the wall-clock
feed would come from per-host heartbeats in a real deployment.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerPolicy:
    ema_alpha: float = 0.1
    slow_factor: float = 1.3  # flagged when EMA > factor * median
    min_samples: int = 8


class StepTimeMonitor:
    """Tracks per-host step-time EMAs and flags persistent stragglers."""

    def __init__(self, num_hosts: int, policy: StragglerPolicy = StragglerPolicy()):
        self.policy = policy
        self.ema = np.zeros(num_hosts)
        self.count = np.zeros(num_hosts, dtype=np.int64)

    def observe(self, host_times: np.ndarray):
        a = self.policy.ema_alpha
        fresh = self.count == 0
        self.ema = np.where(fresh, host_times, (1 - a) * self.ema + a * host_times)
        self.count += 1

    def stragglers(self) -> List[int]:
        if self.count.size == 0 or int(self.count.min()) < self.policy.min_samples:
            return []
        med = float(np.median(self.ema))
        return [
            i for i, t in enumerate(self.ema) if t > self.policy.slow_factor * med
        ]


def plan_rebalance(
    ema_times: np.ndarray, shards_per_host: np.ndarray
) -> np.ndarray:
    """Re-assign data shards so per-host (time-per-shard * shards) equalizes.

    Returns the new integer shard allocation with the same total. Hosts whose
    throughput (1/time) is higher receive proportionally more shards."""
    total = int(shards_per_host.sum())
    speed = 1.0 / np.maximum(ema_times, 1e-9)
    ideal = speed / speed.sum() * total
    alloc = np.floor(ideal).astype(np.int64)
    # distribute the remainder to the largest fractional parts
    rem = total - int(alloc.sum())
    order = np.argsort(-(ideal - alloc))
    alloc[order[:rem]] += 1
    return alloc
