"""Elastic scaling: resume a job on a different device count / mesh shape.

Checkpoints store *global* arrays (repro.checkpoint), so elasticity is:
build the new mesh, recompute PartitionSpecs from the same rules, and
device_put the restored arrays with the new shardings. The ScratchPipe
planner/host-table state is device-count independent (host state). The data
stream fast-forwards deterministically.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.checkpoint import CheckpointManager
from repro.models import api
from repro.parallel.sharding import mesh_axes, tree_shardings, zero1_spec


def reshard_restore(
    ckpt: CheckpointManager,
    cfg,
    new_mesh: Mesh,
    *,
    with_opt_state_like=None,
    step: Optional[int] = None,
) -> Tuple[object, int]:
    """Restore model params (and optionally optimizer state) from ``ckpt``
    onto ``new_mesh`` — the mesh used at save time is irrelevant."""
    ax = mesh_axes(new_mesh)
    target = api.abstract_params(cfg, ax)
    specs = api.param_specs(cfg, ax)
    sh = tree_shardings(new_mesh, specs)
    if with_opt_state_like is None:
        return ckpt.restore(target, step=step, shardings=sh)
    target = {"params": target, "opt": with_opt_state_like}
    opt_specs = jax.tree.map(
        lambda l, s=None: None, with_opt_state_like
    )  # replicated opt restore fallback
    sh_full = {"params": sh, "opt": jax.tree.map(lambda _: None, with_opt_state_like)}
    state, step = ckpt.restore(target, step=step)
    return state, step
